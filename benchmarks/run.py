"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Set BENCH_QUICK=1 for a fast
smoke pass; full runs also write JSON artifacts under
``benchmarks/artifacts/`` (consumed by EXPERIMENTS.md).

Modules:
  fig6_d_sweep    — Fig. 6 (regeneration time & bandwidth vs d)
  fig7_bandwidth  — Fig. 7 (capacity-variance sweep)
  fig8_alpha      — Fig. 8 (MSR -> MBR storage sweep)
  fig10_rctree    — Fig. 10 (RCTREE MDS collapse, data-plane RLNC sim)
  kernel_gf       — GF(2^8) Pallas kernel cost model + timings
  ft_recovery     — beyond-paper: checkpoint-recovery planning on TPU fleet
  roofline        — reads the dry-run artifacts (launch/dryrun.py) if present
"""
from __future__ import annotations

import importlib
import sys
import traceback

MODULES = [
    "fig6_d_sweep",
    "fig7_bandwidth",
    "fig8_alpha",
    "fig10_rctree",
    "kernel_gf",
    "ft_recovery",
    "roofline",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ModuleNotFoundError as e:
            if f"benchmarks.{mod_name}" in str(e):
                continue  # optional module not built yet
            raise
        try:
            for r in mod.run():
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            sys.stdout.flush()
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark modules failed: {failures}")


if __name__ == "__main__":
    main()
