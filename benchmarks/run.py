"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` (or ``BENCH_QUICK=1``
in the environment) selects a fast smoke pass (fewer shapes / Monte-Carlo
batches of 80 instead of 120 trials), ``--seed`` (or ``BENCH_SEED``) the
root seed, ``--engine`` (or ``BENCH_ENGINE``) the planning engine the
fig6/7/8 drivers sweep with (default "batched", the golden-pinned
configuration; "jax" opts into the jit tier), and ``--modules`` restricts
the run to a subset (``planning`` is an alias for the fig6/7/8 trio CI
uses); every run also writes JSON artifacts under ``benchmarks/artifacts/``
(consumed by EXPERIMENTS.md).

Every run additionally consolidates the planning-relevant results into
``BENCH_planning.json`` at the repo root — per-figure-row ``us_per_call``
plus per-scheme mean planner wall time (``plan_ms``) aggregated from the
fig6/fig7/fig8 artifacts, and a ``plans`` section with the *deterministic*
per-point plan values (norm_time / norm_traffic / time_s; no timings) that
``benchmarks/golden/planning_quick_seed0.json`` pins bitwise in CI — so
both the perf trajectory and the planned values of the batched planning
engine (repro.core.batched) are machine-trackable across PRs.  Since
schema v2 the summary also carries a ``profile`` section (per-stage
planner wall times from ``repro.obs.PlannerProfile`` over a seeded
interior-alpha batch, per batched scheme) and a ``schema_version`` +
``meta`` header (seed, quick flag, git describe — resolved at import,
before any artifact writes can dirty the tree).  The summary additionally
carries an ``engine_jax`` A/B section: steady-state batched-vs-jax
per-plan wall time and plans-per-second for fr/ftr on the profile batch,
compile warm-up excluded (omitted with ``available: false`` when jax is
not importable).

Modules:
  fig6_d_sweep    — Fig. 6 (regeneration time & bandwidth vs d)
  fig7_bandwidth  — Fig. 7 (capacity-variance sweep)
  fig8_alpha      — Fig. 8 (MSR -> MBR storage sweep)
  fig10_rctree    — Fig. 10 (RCTREE MDS collapse, data-plane RLNC sim)
  kernel_gf       — GF(2^8) Pallas kernel cost model + timings
  ft_recovery     — beyond-paper: checkpoint-recovery planning on TPU fleet
  fleet_scale     — beyond-paper: event-driven fleet simulator sweep
  roofline        — reads the dry-run artifacts (launch/dryrun.py) if present

One root seed (``BENCH_SEED``, default 0) is threaded into every module
whose ``run`` accepts ``root_seed`` — the fleet sweep derives all of its
scenario seeds from it, which is what makes ``BENCH_fleet.json`` bitwise
reproducible across runs on the same machine.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import traceback

MODULES = [
    "fig6_d_sweep",
    "fig7_bandwidth",
    "fig8_alpha",
    "fig10_rctree",
    "kernel_gf",
    "ft_recovery",
    "fleet_scale",
    "roofline",
]

PLANNING_MODULES = ("fig6_d_sweep", "fig7_bandwidth", "fig8_alpha")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _scheme_plan_ms(ran_modules) -> dict:
    """Mean per-scheme planner wall time over the fig6/7/8 artifacts THIS
    run produced (stale artifact files from earlier runs are ignored so the
    summary never mixes trial counts or quick/full settings)."""
    from .common import ARTIFACT_DIR

    acc: dict = {}
    for mod in PLANNING_MODULES:
        if mod not in ran_modules:
            continue
        path = os.path.join(ARTIFACT_DIR, f"{mod}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            data = json.load(f)
        for point in data.get("points", []):
            for scheme, vals in point.items():
                if isinstance(vals, dict) and "plan_ms" in vals:
                    acc.setdefault(scheme, []).append(vals["plan_ms"])
    return {s: sum(v) / len(v) for s, v in acc.items() if v}


def _plan_values(ran_modules) -> dict:
    """Deterministic per-point plan values from THIS run's fig6/7/8
    artifacts: everything except the wall-time fields.  These are pure
    functions of (seed, quick) — the exact witness oracle has no solver
    noise — so CI pins them bitwise (benchmarks/golden/)."""
    from .common import ARTIFACT_DIR

    out: dict = {}
    for mod in PLANNING_MODULES:
        if mod not in ran_modules:
            continue
        path = os.path.join(ARTIFACT_DIR, f"{mod}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            data = json.load(f)
        pts = []
        for point in data.get("points", []):
            pt = {}
            for key, vals in point.items():
                if isinstance(vals, dict):
                    pt[key] = {m: v for m, v in vals.items() if m != "plan_ms"}
                else:
                    pt[key] = vals
            pts.append(pt)
        out[mod] = pts
    return out


def _registry_info() -> dict:
    """The scheme family as the registry declares it — recorded in the
    summary so a BENCH_planning.json is self-describing about which schemes
    the (registry-enumerated, not hardcoded) fig drivers swept."""
    from repro.core import scheme_names

    return {"schemes": list(scheme_names()),
            "batched": list(scheme_names(batched=True)),
            "jax": list(scheme_names(jax=True))}


def _profile_section(quick: bool, seed: int) -> dict:
    """Per-stage planner profile (ISSUE 7): run every batched scheme once
    over a seeded interior-alpha batch with a ``repro.obs.PlannerProfile``
    attached, and record stage wall times / counters.  The interior alpha
    (halfway MSR -> MBR) is deliberate: it exercises fr's star_bisection +
    witness stages and ftr's full candidate/local-search pipeline, which
    the pure-MSR closed form would skip.  Wall times are machine noise by
    nature; the golden guard only pins ``plans``, never this section."""
    import numpy as np

    from repro.core import CodeParams, mbr_point, plan_many, scheme_names
    from repro.obs import PlannerProfile

    B = 64 if quick else 256
    M, k, d, n = 600.0, 3, 6, 12
    a_msr = M / k
    a_mbr, _ = mbr_point(M, k, d)
    params = CodeParams(n=n, k=k, d=d, M=M, alpha=0.5 * (a_msr + a_mbr))
    rng = np.random.default_rng([seed, 0x0B5])
    caps = rng.uniform(10.0, 120.0, size=(B, d + 1, d + 1))
    idx = np.arange(d + 1)
    caps[:, idx, idx] = 0.0
    out = {}
    for scheme in scheme_names(batched=True):
        prof = PlannerProfile()
        plan_many(caps, params, scheme, engine="batched", profile=prof)
        out[scheme] = prof.summary()
    return out


def _engine_jax_section(quick: bool, seed: int) -> dict:
    """A/B wall time of the NumPy batched engine vs the jit-compiled jax
    tier on the same seeded interior-alpha batch the ``profile`` section
    uses (fr's star bisection + witness, ftr's full candidate/local-search
    pipeline).  Jit compilation is warmed up outside the timed region —
    the numbers are steady-state per-plan costs, min-of-3.

    Honesty note: these are *measurements*, not marketing.  On a 1-core
    CPU container XLA's per-row cost exceeds NumPy's SIMD row cost and the
    lockstep jit program cannot compact converged lanes the way the NumPy
    engine does, so the jax tier is typically SLOWER here for ftr; its
    value on this hardware is parity-guarded accelerator readiness (see
    repro.core.jax_engine).  Wall times are machine noise by nature; the
    golden guard never pins this section.  Omitted when jax is absent.
    """
    import time

    import numpy as np

    from repro.core import CodeParams, mbr_point, plan_many, scheme_names

    jax_capable = scheme_names(jax=True)
    if not jax_capable:
        return {"available": False,
                "note": "jax not importable in this environment"}
    B = 64 if quick else 256
    M, k, d, n = 600.0, 3, 6, 12
    a_mbr, _ = mbr_point(M, k, d)
    params = CodeParams(n=n, k=k, d=d, M=M, alpha=0.5 * (M / k + a_mbr))
    rng = np.random.default_rng([seed, 0x0B5])
    caps = rng.uniform(10.0, 120.0, size=(B, d + 1, d + 1))
    idx = np.arange(d + 1)
    caps[:, idx, idx] = 0.0

    def best_of(fn, reps=3):
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best

    section = {"available": True, "batch": B, "d": d,
               "cpu_count": os.cpu_count(), "schemes": {}}
    for scheme in ("fr", "ftr"):
        plan_many(caps, params, scheme, engine="jax")      # compile warm-up
        t_np = best_of(lambda: plan_many(caps, params, scheme,
                                         engine="batched"))
        t_jx = best_of(lambda: plan_many(caps, params, scheme,
                                         engine="jax"))
        section["schemes"][scheme] = {
            "batched_plan_ms": round(t_np / B * 1e3, 4),
            "jax_plan_ms": round(t_jx / B * 1e3, 4),
            "batched_plans_per_s": round(B / t_np, 1),
            "jax_plans_per_s": round(B / t_jx, 1),
            "jax_speedup": round(t_np / t_jx, 3),
        }
    return section


def _write_planning_summary(rows_by_module: dict) -> None:
    from .common import BENCH_SCHEMA_VERSION, bench_engine, run_meta

    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    seed = int(os.environ.get("BENCH_SEED", "0"))
    summary = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "meta": run_meta(seed, engine=bench_engine()),
        "quick": quick,
        "seed": seed,
        "registry": _registry_info(),
        "rows": {
            r["name"]: round(r["us_per_call"], 3)
            for mod in PLANNING_MODULES
            for r in rows_by_module.get(mod, [])
        },
        "schemes": {s: {"plan_ms": round(ms, 4)}
                    for s, ms in _scheme_plan_ms(rows_by_module).items()},
        "plans": _plan_values(rows_by_module),
        "profile": _profile_section(quick, seed),
        "engine_jax": _engine_jax_section(quick, seed),
    }
    path = os.path.join(REPO_ROOT, "BENCH_planning.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True, allow_nan=False)


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        description="run the benchmark modules (CSV to stdout, JSON "
                    "artifacts under benchmarks/artifacts/)")
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke pass (same as BENCH_QUICK=1)")
    ap.add_argument("--seed", type=int, default=None,
                    help="root seed (same as BENCH_SEED; default 0)")
    ap.add_argument("--engine", default=None,
                    choices=("batched", "scalar", "jax"),
                    help="planning engine for the fig6/7/8 drivers (same "
                         "as BENCH_ENGINE; default batched — the "
                         "golden-pinned configuration)")
    ap.add_argument("--modules", nargs="+", default=None, metavar="MOD",
                    help="subset of modules to run; 'planning' expands to "
                         f"{'/'.join(PLANNING_MODULES)}")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = _parse_args(argv)
    # the flags are sugar over the env vars every module already reads
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    if args.seed is not None:
        os.environ["BENCH_SEED"] = str(args.seed)
    if args.engine is not None:
        os.environ["BENCH_ENGINE"] = args.engine
    modules = MODULES
    if args.modules is not None:
        modules = []
        for m in args.modules:
            modules.extend(PLANNING_MODULES if m == "planning" else [m])
        unknown = [m for m in modules if m not in MODULES]
        if unknown:
            raise SystemExit(f"unknown benchmark modules {unknown}; "
                             f"available: {MODULES}")
    print("name,us_per_call,derived")
    root_seed = int(os.environ.get("BENCH_SEED", "0"))
    failures = []
    rows_by_module: dict = {}
    for mod_name in modules:
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ModuleNotFoundError as e:
            if f"benchmarks.{mod_name}" in str(e):
                continue  # optional module not built yet
            raise
        try:
            kwargs = ({"root_seed": root_seed}
                      if "root_seed" in inspect.signature(mod.run).parameters
                      else {})
            rows = list(mod.run(**kwargs))
            rows_by_module[mod_name] = rows
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            sys.stdout.flush()
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if any(m in rows_by_module for m in PLANNING_MODULES):
        try:
            _write_planning_summary(rows_by_module)
        except Exception:
            failures.append("BENCH_planning.json")
            traceback.print_exc()
    else:
        # a --modules run without any fig6/7/8 module must not clobber the
        # tracked BENCH_planning.json with an empty summary
        print("note: no planning module ran; BENCH_planning.json untouched",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark modules failed: {failures}")


if __name__ == "__main__":
    main()
