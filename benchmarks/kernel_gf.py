"""GF(2^8) matmul kernel micro-benchmark.

On this CPU container the Pallas kernel runs in interpret mode, so absolute
wall time is NOT the deployment number; the derived column reports the
bit-plane MXU cost model instead (64 int8 dots per GF MAC -> ceiling of
197e12 * 2 / 64 ≈ 6.2e12 GF-MAC/s per v5e chip) alongside the interpret-
mode and numpy-table timings for regression tracking.
"""
from __future__ import annotations

import time

import numpy as np

from repro.coding.gf import GF8
from repro.kernels.ops import gf_matmul

from .common import quick_mode, row, save_artifact

PEAK_BF16 = 197e12
GF_MAC_CEILING = PEAK_BF16 * 2 / 64  # int8 MXU rate / 64 bit-plane dots


def _time(fn, reps=3):
    fn()  # warm up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run():
    quick = quick_mode()
    shapes = [(128, 512, 128)] if quick else [
        (128, 512, 128), (256, 1024, 256), (512, 2048, 128)]
    rng = np.random.default_rng(0)
    rows, artifact = [], {"gf_mac_ceiling_per_chip": GF_MAC_CEILING,
                          "points": []}
    for (m, k, n) in shapes:
        a = rng.integers(0, 256, (m, k), dtype=np.uint8)
        b = rng.integers(0, 256, (k, n), dtype=np.uint8)
        t_pallas = _time(lambda: np.asarray(gf_matmul(a, b)))
        t_numpy = _time(lambda: GF8.matmul(a, b))          # blocked lookup
        t_rowloop = _time(lambda: GF8.matmul_rowloop(a, b))  # old per-k loop
        np.testing.assert_array_equal(GF8.matmul(a, b), GF8.matmul_rowloop(a, b))
        macs = m * k * n
        tpu_est_s = macs / GF_MAC_CEILING
        artifact["points"].append({
            "shape": [m, k, n], "interpret_s": t_pallas, "numpy_s": t_numpy,
            "numpy_rowloop_s": t_rowloop, "tpu_ceiling_s": tpu_est_s})
        rows.append(row(
            f"kernel_gf/{m}x{k}x{n}",
            t_pallas * 1e6,
            f"numpy={t_numpy*1e6:.0f}us tpu_ceiling={tpu_est_s*1e6:.2f}us "
            f"macs={macs}"))
        rows.append(row(
            f"kernel_gf/table_{m}x{k}x{n}",
            t_numpy * 1e6,
            f"blocked={t_numpy*1e6:.0f}us rowloop={t_rowloop*1e6:.0f}us "
            f"speedup={t_rowloop/max(t_numpy, 1e-12):.1f}x"))
    save_artifact("kernel_gf", artifact)
    return rows
