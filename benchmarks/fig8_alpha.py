"""Fig. 8: effect of per-node storage alpha, swept MSR -> MBR
(n=20, k=5, d=10, M=1GB).

Paper claim: normalized regeneration times of FR/TR/FTR are insensitive to
alpha; tree schemes still pay extra total bandwidth.
"""
from __future__ import annotations

from repro.core import CodeParams, mbr_point, scheme_names
from repro.storage import compare_schemes, uniform

from .common import (bench_engine, quick_mode, row, save_artifact,
                     timed_best_of)

N, K, D, M_BLOCKS = 20, 5, 10, 8000.0
SCHEMES = scheme_names(batched=True)   # registry-driven scheme column


def run():
    quick = quick_mode()
    trials = 80 if quick else 120   # batched engine affords big batches
    steps = 3 if quick else 6
    a_msr = M_BLOCKS / K
    a_mbr, _ = mbr_point(M_BLOCKS, K, D)
    rows, artifact = [], {"params": {"n": N, "k": K, "d": D, "M": M_BLOCKS,
                                     "trials": trials}, "points": []}
    engine = bench_engine()
    # untimed warm-up: one-time initialization out of the first row (at the
    # timed batch size under jax — one executable per (batch, d) shape)
    compare_schemes(CodeParams.msr(n=N, k=K, d=D, M=M_BLOCKS), uniform(),
                    SCHEMES, trials if engine == "jax" else 2, seed=0,
                    engine=engine)
    for i in range(steps):
        frac = i / (steps - 1)
        alpha = a_msr + (a_mbr - a_msr) * frac
        p = CodeParams(n=N, k=K, d=D, M=M_BLOCKS, alpha=alpha)
        stats, secs = timed_best_of(
            lambda: compare_schemes(p, uniform(), SCHEMES, trials,
                                    seed=80 + i, engine=engine))
        point = {"alpha": alpha, "alpha_over_msr": alpha / a_msr,
                 "beta_uniform": p.beta}
        for s in SCHEMES:
            st = stats[s]
            point[s] = {"norm_time": st.mean_norm_time,
                        "norm_traffic": st.mean_norm_traffic,
                        "time_s": st.mean_time,
                        "plan_ms": st.plan_seconds * 1e3}
        artifact["points"].append(point)
        rows.append(row(
            f"fig8/alpha={alpha:.0f}",
            secs / (trials * len(SCHEMES)) * 1e6,
            "norm_time " + " ".join(
                f"{s}={stats[s].mean_norm_time:.3f}" for s in SCHEMES)))
    save_artifact("fig8_alpha", artifact)
    return rows
