"""CI cross-engine guard: the jax planning tier must match the NumPy
engines on seeded instances.

Usage:

    python benchmarks/check_engine_parity.py [--mode warn|fail]

Plans a grid of seeded overlay batches (MSR and interior-alpha operating
points, several d/k shapes) with every jax-capable scheme on all three
engines and compares:

* ``parents`` — bitwise equal (tree topology is discrete; any divergence
  is a real algorithmic drift, not float noise),
* ``star`` times — bitwise equal (pure min/max/divide data flow, where
  float64 jit permits exactness),
* everything else (times/traffic/betas/lower_bounds of fr/tr/ftr, star
  traffic) — relative error <= 1e-9.  The jax kernels run the same
  float64 recurrences in the same order, but XLA may re-associate
  reductions (e.g. the traffic sum), which permits ~1-ulp differences;
  measured drift is ~1e-14, so 1e-9 has five orders of headroom while
  still catching any use of a different formula.

The jax engine is additionally tied to the *scalar* oracle on a row
subset, so this guard transitively covers jax -> batched -> scalar.

Under GITHUB_ACTIONS the guard also asserts that the checked-in
``BENCH_planning.json`` meta records a non-dirty git state: a clean CI
checkout recording "-dirty" means metadata was resolved after the run's
own artifact writes (the bug fixed by resolving git state at
``benchmarks.common`` import) or that generated files were not committed.

``--mode warn`` (pull requests) prints GitHub warning annotations and
exits 0; ``--mode fail`` (pushes to main) exits 1 on any mismatch.
Exits 0 with a notice when jax is not importable (the tier is optional by
design — the registry then declares ``jax=None`` everywhere).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

REL_TOL = 1e-9          # documented cross-engine float tolerance
SCALAR_ROWS = 3         # rows per config tied directly to the scalar oracle

# (d, k, B, msr): small shapes keep per-shape jit compilation (the cost
# driver on CI) in the seconds range while still covering k=d, interior
# alpha, and a non-power-of-two batch that exercises the padding path.
CONFIGS = [
    (4, 2, 7, True),
    (4, 4, 5, False),
    (6, 3, 16, True),
    (6, 3, 9, False),
]


def _overlays(rng, B, d):
    caps = rng.uniform(10.0, 120.0, size=(B, d + 1, d + 1))
    idx = np.arange(d + 1)
    caps[:, idx, idx] = 0.0
    return caps


def _params(d, k, msr):
    from repro.core import CodeParams, mbr_point
    M = 600.0
    if msr:
        return CodeParams.msr(n=d + 2, k=k, d=d, M=M)
    a_mbr, _ = mbr_point(M, k, d)
    return CodeParams(n=d + 2, k=k, d=d, M=M, alpha=0.5 * (M / k + a_mbr))


def _rel_err(a, b):
    a, b = np.asarray(a, dtype=float), np.asarray(b, dtype=float)
    both_inf = np.isinf(a) & np.isinf(b) & (np.sign(a) == np.sign(b))
    diff = np.where(both_inf, 0.0, np.abs(a - b))
    scale = np.maximum(1.0, np.abs(np.where(both_inf, 0.0, a)))
    return float((diff / scale).max()) if diff.size else 0.0


def _check_dirty_meta(problems):
    path = os.path.join(REPO_ROOT, "BENCH_planning.json")
    if not os.path.exists(path):
        return
    with open(path) as f:
        git = (json.load(f).get("meta") or {}).get("git")
    if git and git.endswith("-dirty"):
        problems.append(
            f"BENCH_planning.json meta records git={git!r} on a CI "
            f"checkout: benchmark metadata must capture a clean tree "
            f"(resolve git state before artifact writes / commit "
            f"generated files)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("warn", "fail"), default="warn")
    args = ap.parse_args()

    problems: list = []
    if os.environ.get("GITHUB_ACTIONS") == "true":
        _check_dirty_meta(problems)

    from repro.core import plan_many, scheme_names
    from repro.core.api import get_scheme

    jax_capable = scheme_names(jax=True)
    if not jax_capable:
        print("engine parity: jax not importable here; nothing to check "
              "(the registry declares jax=None for every scheme)")
        return _report(problems, args.mode)

    checked = 0
    for d, k, B, msr in CONFIGS:
        params = _params(d, k, msr)
        rng = np.random.default_rng([d, k, B, int(msr), 0xE191])
        caps = _overlays(rng, B, d)
        label = f"d={d} k={k} B={B} {'msr' if msr else 'interior'}"
        for scheme in jax_capable:
            rb = plan_many(caps, params, scheme, engine="batched")
            rj = plan_many(caps, params, scheme, engine="jax")
            rs = plan_many(caps[:SCALAR_ROWS], params, scheme,
                           engine="scalar")

            def bad(msg):
                problems.append(f"{label} {scheme}: {msg}")

            if not (rj.parents == rb.parents).all():
                bad("parents differ from batched engine (must be bitwise)")
            if not (rj.parents[:SCALAR_ROWS] == rs.parents).all():
                bad("parents differ from scalar oracle (must be bitwise)")
            if scheme == "star":
                if not (rj.times == rb.times).all():
                    bad(f"star times not bitwise equal "
                        f"(max rel err {_rel_err(rb.times, rj.times):.3e})")
            else:
                e = _rel_err(rb.times, rj.times)
                if e > REL_TOL:
                    bad(f"times rel err {e:.3e} > {REL_TOL:g}")
            for field in ("traffic", "betas", "lower_bounds"):
                vb, vj = getattr(rb, field), getattr(rj, field)
                if vb is None and vj is None:
                    continue
                e = _rel_err(vb, vj)
                if e > REL_TOL:
                    bad(f"{field} rel err {e:.3e} > {REL_TOL:g}")
            e = _rel_err(rs.times, rj.times[:SCALAR_ROWS])
            if e > REL_TOL:
                bad(f"times vs scalar oracle rel err {e:.3e} > {REL_TOL:g}")
            checked += 1
    spec_caps = {s: get_scheme(s).jax is not None for s in scheme_names()}
    print(f"engine parity: {checked} (config, scheme) pairs checked over "
          f"{len(CONFIGS)} configs; jax-capable schemes: "
          f"{[s for s, ok in spec_caps.items() if ok]}")
    return _report(problems, args.mode)


def _report(problems, mode) -> int:
    if not problems:
        print("engine parity OK")
        return 0
    for msg in problems:
        marker = "warning" if mode == "warn" else "error"
        print(f"::{marker} title=engine parity::{msg}")
    print(f"engine parity: {len(problems)} problem(s)")
    return 1 if mode == "fail" else 0


if __name__ == "__main__":
    sys.exit(main())
