"""Roofline table from the dry-run artifacts (deliverable g).

Reads benchmarks/artifacts/dryrun/summary.json (written by
``python -m repro.launch.dryrun --all``) and emits one row per
(arch x shape x mesh) cell with the three roofline terms, the dominant
bottleneck, peak per-device memory and the MODEL_FLOPS/HLO_FLOPS ratio.
Also renders the markdown table consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from .common import row, save_artifact

SUMMARY = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun",
                       "summary.json")


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.1e}"
    if x < 10:
        return f"{x:.3f}"
    return f"{x:.1f}"


def markdown_table(cells) -> str:
    head = ("| arch | shape | mesh | peak GB/dev | t_comp s | t_mem s | "
            "t_coll s | dominant | roofline frac | useful frac | note |")
    sep = "|" + "---|" * 11
    lines = [head, sep]
    for c in cells:
        if c["ok"] == "skip":
            lines.append(f"| {c['arch']} | {c['shape']} | - | - | - | - | - |"
                         f" SKIP | - | - | {c['why']} |")
            continue
        if not c["ok"]:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | - | "
                         f"- | - | - | FAIL | - | - | see artifact |")
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['memory']['peak_bytes_per_device'] / 1e9:.2f} "
            f"| {_fmt(r['t_compute'])} | {_fmt(r['t_memory'])} "
            f"| {_fmt(r['t_collective'])} | {r['dominant'][2:]} "
            f"| {r['roofline_fraction']:.4f} | {c['useful_fraction']:.3f} | |")
    return "\n".join(lines)


def run():
    if not os.path.exists(SUMMARY):
        return [row("roofline/missing", 0.0,
                    "run `python -m repro.launch.dryrun --all` first")]
    cells = json.load(open(SUMMARY))
    ok = [c for c in cells if c["ok"] is True]
    save_artifact("roofline_table", {"markdown": markdown_table(cells)})
    rows = []
    for c in ok:
        r = c["roofline"]
        rows.append(row(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            c["compile_s"] * 1e6,
            f"dom={r['dominant'][2:]} frac={r['roofline_fraction']:.4f} "
            f"peakGB={c['memory']['peak_bytes_per_device']/1e9:.2f} "
            f"useful={c['useful_fraction']:.3f}"))
    nbad = len([c for c in cells if c["ok"] is False])
    rows.append(row("roofline/summary", 0.0,
                    f"{len(ok)} compiled, {nbad} failed, "
                    f"{len([c for c in cells if c['ok'] == 'skip'])} skipped"))
    return rows
