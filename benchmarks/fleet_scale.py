"""Fleet-scale sweep: cluster size x failure rate x repair policy.

Runs the event-driven fleet simulator (``repro.fleet``) over the scenario
library and writes two artifacts:

* ``benchmarks/artifacts/fleet_scale.json`` — the usual per-module record;
* ``BENCH_fleet.json`` at the repo root — the machine-trackable fleet
  metrics (backlog, p50/p99 regeneration time under contention,
  vulnerability window, MTTDL estimate) per configuration.

Determinism: every configuration's simulator seed is derived from one root
seed (threaded in by ``benchmarks/run.py``, or ``--seed`` on the CLI) and
the config name via crc32, and no wall-clock measurement enters the JSON —
``BENCH_fleet.json`` is bitwise reproducible across runs on one machine.
Wall time only feeds the ``us_per_call`` CSV column.

The sweep includes a repair-lifecycle column (PR 3): the abort-heavy
``flaky_providers`` scenario per policy with partial-progress carryover and
in-flight plan migration off (``..._<pol>``, the default path), carryover
only (``..._carry``), and carryover + migration (``..._mig``).  Rows whose
name carries no lifecycle suffix run the pre-PR-3 dynamics bitwise;
``benchmarks/golden/fleet_quick_seed0.json`` pins their quick-mode values
and CI fails on any diff (see tests/test_fleet.py, ci.yml, and
``benchmarks/check_fleet_golden.py``).

A plan-vs-reality robustness column (ISSUE 6) runs the ``stragglers``
(silent link brownouts) and ``foggy_estimates`` (stale/noisy capacity
estimates) scenarios with mitigation off and on (``..._robust``:
watchdog + retry/backoff + degraded-d admission); each summary carries the
plan-error distribution (realized vs predicted (re)plan ETA).

Observability (ISSUE 7): ``--trace`` re-runs every configuration with the
flight recorder on, asserts the traced summary is bitwise identical to the
untraced one (tracing is observation, never perturbation), and writes one
``<name>.jsonl`` event log plus one ``<name>.trace.json`` Chrome/Perfetto
trace per config under ``benchmarks/artifacts/traces/``.  Both JSON roots
are strict JSON since schema v2 — non-finite floats (the quiet scenarios'
``mttdl_estimate``) serialize as ``null``, never the invalid ``Infinity``
literal — and carry a ``schema_version`` + ``meta`` header (root seed,
quick flag, git describe).

Region scale (ISSUE 8): two additions ride on the incremental sharing
engine.  ``ensemble_*`` config rows run K independent clusters (distinct
seed streams, one scenario) through the lockstep multi-cluster driver
(``repro.fleet.ensemble``) and report the pooled region-level summary
plus cluster-bootstrap confidence intervals under a ``cis`` key; K is
overridable with ``--clusters``.  A separate ``perf`` section measures
event-loop throughput (wall-clock us per event) on three fixed rows and
records the frozen PR-7 (full-rescan engine) reference alongside — the
speedup the incremental engine is accountable for.  The ``configs``
section stays bitwise reproducible; ``perf`` is wall clock by design and
is guarded by ``benchmarks/check_fleet_perf.py`` (machine-normalized,
like the planning tripwire), never by the bitwise golden.

Coded data plane (ISSUE 10): ``dataplane_*`` config rows run the fleet
with real payloads — degraded reads as k-fragment transfers, repairs
producing RLNC-coded blocks through ``repro.coding.rlnc`` with decode
verification — and carry the read-latency percentiles, wire-byte
counters, and a ``dataplane_links`` top-10 (per-link repair/read bytes)
next to the usual summary.  One row replays an open-loop arrival trace
generated to ``benchmarks/artifacts/read_workload.jsonl``.

CLI: ``python -m benchmarks.fleet_scale [--quick] [--seed N] [--trace]
[--clusters K]`` (CI runs the ``--quick`` smoke, which asserts the
artifact exists and backlog is finite, plus a ``--trace`` pass checked
by check_trace.py).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import zlib

from repro.core import CodeParams
from repro.fleet import SCENARIOS, ClusterEnsemble, FleetSimulator, \
    ReadTrace, Scenario, generate_trace, make_policy, mitigated, simulate
from repro.fleet.scenario import uniform_matrix
from repro.obs import json_sanitize

from .common import BENCH_SCHEMA_VERSION, quick_mode, row, run_meta, \
    save_artifact

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts", "traces")

# ~events per simulation: duration is sized as EVENT_BUDGET failures in
# expectation, so sweeping the failure rate changes contention, not cost
EVENT_BUDGET_QUICK = 40
EVENT_BUDGET = 150


def _config_seed(root_seed: int, name: str) -> int:
    return (root_seed * 1_000_003 + zlib.crc32(name.encode())) % (1 << 31)


# -- event-loop throughput rows (ISSUE 8) -----------------------------------
# Fixed scenarios sized so the event loop, not the planner, is the cost:
# heavy degraded-read traffic (eventloop), abort churn (churn), and a
# moderate read mix (readmix).  ``PR7_US_PER_EVENT`` freezes the
# pre-incremental-engine (full-rescan) measurement of the SAME rows on the
# reference machine — best of 5, identical event sequences (the engines
# agree bitwise on every metric) — so ``speedup_vs_pr7`` in the perf
# section is an apples-to-apples event-loop ratio, not a machine artifact.
PERF_REPEATS = 3


def _perf_rows():
    cap = uniform_matrix(0.3, 8.0)
    yield "eventloop_n96_star", Scenario(
        num_nodes=96, duration=2500.0, failure_rate=6e-3,
        capacity_model=cap, max_concurrent=64,
        read_rate=8.0, read_duration=60.0), "star"
    yield "churn_n64_star", Scenario(
        num_nodes=64, duration=1500.0, failure_rate=1.2e-2,
        capacity_model=cap, max_concurrent=32), "star"
    yield "readmix_n96_star", Scenario(
        num_nodes=96, duration=2000.0, failure_rate=4e-3,
        capacity_model=cap, max_concurrent=48,
        read_rate=2.0, read_duration=30.0), "star"


PR7_US_PER_EVENT = {
    "eventloop_n96_star": 231.2,
    "churn_n64_star": 719.6,
    "readmix_n96_star": 180.4,
}


def _ensemble_rows(quick: bool, clusters: int = 0):
    """(name, scenario, policy, K) rows for the lockstep multi-cluster
    driver.  ``clusters`` overrides the per-row default K when > 0."""
    cap = uniform_matrix(0.3, 8.0)
    if quick:
        rows = [("ensemble_n96", Scenario(
            num_nodes=96, duration=150.0, failure_rate=4e-3,
            capacity_model=cap, max_concurrent=16), "star", 2)]
    else:
        rows = [
            ("ensemble_n96", Scenario(
                num_nodes=96, duration=600.0, failure_rate=4e-3,
                capacity_model=cap, max_concurrent=32), "star", 4),
            ("ensemble_n256", Scenario(
                num_nodes=256, duration=300.0, failure_rate=2e-3,
                capacity_model=cap, max_concurrent=48), "star", 4),
        ]
    for name, sc, pol, k in rows:
        k = clusters if clusters > 0 else k
        yield f"{name}_K{k}_{pol}", sc, pol, k


ENSEMBLE_CI_KEYS = ("mean_backlog", "regen_p50", "regen_p99",
                    "vulnerability_p99", "unavail_fraction",
                    "mttdl_estimate")


def _params(d: int = 6) -> CodeParams:
    return CodeParams.msr(n=12, k=3, d=d, M=600.0)


def _sweep(quick: bool):
    """Yield (name, scenario, policy_spec) configurations."""
    sizes = (16,) if quick else (16, 32, 64)
    rates = (2e-3,) if quick else (1e-3, 4e-3)
    policies = (("star", "ftr", "flexible") if quick
                else ("star", "fr", "tr", "ftr", "flexible"))
    budget = EVENT_BUDGET_QUICK if quick else EVENT_BUDGET
    for n in sizes:
        for lam in rates:
            duration = budget / (lam * n)
            for pol in policies:
                sc = SCENARIOS["steady"](n, failure_rate=lam,
                                         duration=duration)
                yield f"n{n}_lam{lam:g}_{pol}", sc, pol
    if not quick:
        # scenario-library column at fixed size/rate for the two best
        # policies: rack bursts, capacity weather, degraded reads, tiered
        n, lam = 24, 2e-3
        duration = budget / (lam * n)
        for kind in ("rack_bursts", "capacity_weather", "hot_reads",
                     "tiered"):
            for pol in ("ftr", "flexible"):
                sc = SCENARIOS[kind](n, failure_rate=lam, duration=duration)
                yield f"{kind}_n{n}_{pol}", sc, pol
    # repair-lifecycle column (policy x migration): the abort-heavy
    # flaky_providers scenario, per policy with the lifecycle machinery
    # off (default path — bitwise-guarded), carryover only, and
    # carryover + in-flight migration
    n, lam = 16, 4e-3
    duration = budget / (lam * n)
    for pol in ("flexible",) if quick else ("ftr", "flexible"):
        sc = SCENARIOS["flaky_providers"](n, failure_rate=lam,
                                          duration=duration)
        yield f"flaky_providers_n{n}_{pol}", sc, pol
        yield (f"flaky_providers_n{n}_{pol}_carry",
               dataclasses.replace(sc, carryover=True), pol)
        yield (f"flaky_providers_n{n}_{pol}_mig",
               dataclasses.replace(sc, carryover=True, migration=True), pol)
        # bank-aware migration (ISSUE 8): the simulator scores every
        # candidate scheme's replan by credited residual ETA instead of
        # taking the policy's nominal-time pick
        yield (f"flaky_providers_n{n}_{pol}_bankmig",
               dataclasses.replace(sc, carryover=True, migration=True,
                                   bank_aware_migration=True), pol)
    # plan-vs-reality robustness column (ISSUE 6): silent brownouts
    # (stragglers) and stale/noisy capacity estimates (foggy_estimates),
    # each with mitigation off (the injections alone) and on
    # (``..._robust``: watchdog + retry/backoff + degraded-d).  The
    # plan-error percentiles quantify how far predictions drift from
    # reality; the robust rows show what the watchdog buys back.
    n, lam = 16, 2e-3
    duration = budget / (lam * n)
    for kind in ("stragglers", "foggy_estimates"):
        sc = SCENARIOS[kind](n, failure_rate=lam, duration=duration)
        yield f"{kind}_n{n}_flexible", sc, "flexible"
        yield f"{kind}_n{n}_flexible_robust", mitigated(sc), "flexible"


def _dataplane_rows(quick: bool, root_seed: int):
    """(name, scenario, policy) rows exercising the coded data plane
    (ISSUE 10): reads and repairs as real fragment/block transfers.

    * ``..._storm_...`` — the hot_reads scenario under a capacity storm
      (fast, deep shocks) with decode verification on: every completed
      repair's regenerated blocks must keep the mini code store
      k-of-n decodable.
    * ``..._trace_...`` — the same data plane driven by an open-loop
      arrival trace generated to a JSONL file and replayed (the
      millions-of-arrivals path, exercised here at bench scale).  The
      workload file lands in ``benchmarks/artifacts/`` — NOT under
      ``traces/``, which check_trace.py globs for flight-recorder logs.
    """
    n, lam = 16, 2e-3
    budget = EVENT_BUDGET_QUICK if quick else EVENT_BUDGET
    duration = budget / (lam * n)
    storm = dataclasses.replace(
        SCENARIOS["hot_reads"](n, failure_rate=lam, duration=duration,
                               dataplane=True, dataplane_verify=True),
        shock_period=duration / 8, shock_lo=0.35)
    yield f"dataplane_hot_reads_storm_n{n}_flexible", storm, "flexible"
    art_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts")
    os.makedirs(art_dir, exist_ok=True)
    workload = os.path.join(art_dir, "read_workload.jsonl")
    generate_trace(workload, rate=0.1, duration=duration,
                   seed=_config_seed(root_seed, "read_workload"))
    replay = SCENARIOS["hot_reads"](
        n, failure_rate=lam, duration=duration, dataplane=True,
        read_trace=ReadTrace(path=workload), dataplane_verify=True)
    yield f"dataplane_hot_reads_trace_n{n}_ftr", replay, "ftr"


def _trace_config(name: str, sc, pol: str, params, seed: int,
                  untraced_summary: dict, root_seed: int) -> None:
    """Re-run one configuration with the flight recorder on, assert the
    traced summary equals the untraced one bitwise (tracing must never
    perturb the simulation), and write the JSONL + Chrome trace files."""
    sim = FleetSimulator(dataclasses.replace(sc, trace=True),
                         make_policy(pol), params, seed=seed)
    traced = sim.run().summary()
    assert traced == untraced_summary, \
        f"{name}: traced summary diverged from untraced (tracing perturbed " \
        f"the simulation)"
    sim.recorder.meta.update(config=name, root_seed=root_seed, seed=seed)
    os.makedirs(TRACE_DIR, exist_ok=True)
    sim.recorder.save_jsonl(os.path.join(TRACE_DIR, f"{name}.jsonl"))
    sim.recorder.save_chrome(os.path.join(TRACE_DIR,
                                          f"{name}.trace.json"))


def run(root_seed: int = 0, trace: bool = False, clusters: int = 0):
    quick = quick_mode()
    params = _params()
    rows, configs = [], {}
    for name, sc, pol in _sweep(quick):
        seed = _config_seed(root_seed, name)
        t0 = time.perf_counter()
        summary = simulate(sc, make_policy(pol), params, seed=seed)
        wall = time.perf_counter() - t0
        assert math.isfinite(summary["mean_backlog"]), name
        assert summary["regen_p50"] >= 0 and summary["regen_p99"] >= 0, name
        if trace:
            _trace_config(name, sc, pol, params, seed, summary, root_seed)
        configs[name] = summary
        events = max(summary["completed"] + summary["aborted"], 1)
        rows.append(row(
            f"fleet/{name}", wall / events * 1e6,
            f"backlog={summary['mean_backlog']:.3f} "
            f"p99={summary['regen_p99']:.3f}s "
            f"vuln_p99={summary['vulnerability_p99']:.3f}s "
            f"mig={summary['migrations']:.0f} "
            f"saved={summary['work_saved_fraction']:.2f} "
            f"plan_err={summary['plan_err_mean']:.2f}"))
    # coded data plane rows (ISSUE 10): run the simulator directly so the
    # per-link wire-byte ledger can ride in the artifact next to the
    # summary (``dataplane_links``); ``simulate()`` would discard it
    for name, sc, pol in _dataplane_rows(quick, root_seed):
        seed = _config_seed(root_seed, name)
        t0 = time.perf_counter()
        sim = FleetSimulator(sc, make_policy(pol), params, seed=seed)
        summary = sim.run().summary()
        wall = time.perf_counter() - t0
        assert math.isfinite(summary["mean_backlog"]), name
        assert summary["reads_completed"] > 0, name
        assert summary["decode_failures"] == 0, name
        assert summary["repair_bytes"] > 0 and summary["read_bytes"] > 0, name
        if trace:
            _trace_config(name, sc, pol, params, seed, summary, root_seed)
        configs[name] = dict(summary,
                             dataplane_links=sim.dataplane.top_links(10))
        events = max(summary["completed"] + summary["aborted"], 1)
        rows.append(row(
            f"fleet/{name}", wall / events * 1e6,
            f"reads={summary['reads_completed']} "
            f"read_p99={summary['read_p99']:.3f}s "
            f"repair_GB={summary['repair_bytes'] / 1e9:.1f} "
            f"read_GB={summary['read_bytes'] / 1e9:.1f} "
            f"decode_fail={summary['decode_failures']}"))
    # region-scale ensemble rows: K clusters in lockstep, pooled summary
    # plus cluster-bootstrap CIs.  Deterministic like every config row —
    # the bootstrap rng is seeded from the config seed.
    for name, sc, pol, k in _ensemble_rows(quick, clusters):
        seed = _config_seed(root_seed, name)
        t0 = time.perf_counter()
        ens = ClusterEnsemble(sc, lambda p=pol: make_policy(p), params,
                              clusters=k, root_seed=seed)
        ens.run()
        wall = time.perf_counter() - t0
        summary = ens.pooled().summary()
        assert math.isfinite(summary["mean_backlog"]), name
        cis = ens.cis(ENSEMBLE_CI_KEYS, n_boot=200, seed=seed)
        configs[name] = dict(summary, clusters=k,
                             cis={key: list(v) for key, v in cis.items()})
        events = max(summary["completed"] + summary["aborted"], 1)
        lo, mid, hi = cis["mean_backlog"]
        rows.append(row(
            f"fleet/{name}", wall / events * 1e6,
            f"K={k} backlog={mid:.3f} [{lo:.3f},{hi:.3f}] "
            f"p99={summary['regen_p99']:.3f}s "
            f"vuln_p99={summary['vulnerability_p99']:.3f}s"))
    # event-loop throughput section: wall clock by design, so it lives
    # OUTSIDE ``configs`` (that section stays bitwise reproducible) and is
    # guarded by check_fleet_perf.py instead of the golden
    perf = {}
    for name, sc, pol in _perf_rows():
        seed = _config_seed(root_seed, name)
        best = None
        for _ in range(PERF_REPEATS):
            sim = FleetSimulator(sc, make_policy(pol), params, seed=seed)
            t0 = time.perf_counter()
            sim.run()
            wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, sim.loop_events)
        wall, events = best
        us = wall / events * 1e6
        pr7 = PR7_US_PER_EVENT[name]
        perf[name] = {
            "us_per_event": us,
            "loop_events": events,
            "pr7_us_per_event": pr7,
            "speedup_vs_pr7": pr7 / us,
        }
        rows.append(row(f"fleet_perf/{name}", us,
                        f"events={events} pr7={pr7:.1f}us/ev "
                        f"speedup={pr7 / us:.2f}x"))
    artifact = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "meta": run_meta(root_seed, sweep="quick" if quick else "full"),
        "quick": quick,
        "root_seed": root_seed,
        "configs": configs,
        "perf": perf,
    }
    # strict JSON: `Infinity` is not JSON — sanitize non-finite floats
    # (quiet scenarios' mttdl_estimate) to null and forbid the literal
    artifact = json_sanitize(artifact)
    save_artifact("fleet_scale", artifact)
    with open(os.path.join(REPO_ROOT, "BENCH_fleet.json"), "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True, allow_nan=False)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI smoke)")
    ap.add_argument("--seed", type=int, default=0, help="root seed")
    ap.add_argument("--trace", action="store_true",
                    help="also re-run each config with the flight recorder "
                         "on and write benchmarks/artifacts/traces/")
    ap.add_argument("--clusters", type=int, default=0,
                    help="override K for the ensemble rows (0 = per-row "
                         "defaults)")
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    print("name,us_per_call,derived")
    for r in run(root_seed=args.seed, trace=args.trace,
                 clusters=args.clusters):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    path = os.path.join(REPO_ROOT, "BENCH_fleet.json")
    assert os.path.exists(path), "BENCH_fleet.json was not written"

    def _reject(const):  # strict JSON: Infinity/NaN literals are a bug
        raise ValueError(f"non-strict JSON literal {const} in {path}")

    with open(path) as f:
        data = json.load(f, parse_constant=_reject)
    assert data["schema_version"] == BENCH_SCHEMA_VERSION, "stale schema"
    assert all(math.isfinite(c["mean_backlog"])
               for c in data["configs"].values()), "non-finite backlog"
    print(f"# wrote {path} ({len(data['configs'])} configs)")
    if args.trace:
        n_traces = len([p for p in os.listdir(TRACE_DIR)
                        if p.endswith(".jsonl")])
        print(f"# wrote {n_traces} traces under {TRACE_DIR}")


if __name__ == "__main__":
    main()
