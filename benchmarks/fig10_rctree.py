"""Fig. 10 (Appendix A): probability of successful file reconstruction vs
number of repair rounds — RCTREE collapses, our schemes stay at ~1.

Data-plane simulation with real GF(2^8) coding vectors (the paper uses
GF(2^16); collapse is structural — min-cut < M — so the field size only
affects the negligible random-coding failure probability, DESIGN.md §6).
"""
from __future__ import annotations

from repro.core import CodeParams
from repro.storage import reconstruction_vs_rounds, uniform

from .common import Timer, quick_mode, row, save_artifact

# four (n, k, d) settings in the spirit of Fig. 10 (exact values unreadable
# in the source scan); M chosen so alpha and beta are integral at MSR.
SETTINGS = [
    dict(n=8, k=2, d=4, M=6.0),     # alpha=3,  beta=1
    dict(n=8, k=3, d=5, M=9.0),     # alpha=3,  beta=1
    dict(n=10, k=4, d=6, M=12.0),   # alpha=3,  beta=1
    dict(n=12, k=5, d=8, M=20.0),   # alpha=4,  beta=1
]


def run():
    quick = quick_mode()
    rounds = 6 if quick else 12
    trials = 2 if quick else 8
    rows, artifact = [], {"rounds": rounds, "trials": trials, "curves": []}
    for s in (SETTINGS[:1] if quick else SETTINGS):
        p = CodeParams.msr(**s)
        with Timer() as t:
            bad = reconstruction_vs_rounds(p, "rctree", uniform(), rounds,
                                           trials, seed=10)
            good = reconstruction_vs_rounds(p, "ftr", uniform(), rounds,
                                            trials, seed=10)
        tag = f"n{s['n']}k{s['k']}d{s['d']}"
        artifact["curves"].append({"setting": s, "rctree": bad, "ftr": good})
        rows.append(row(
            f"fig10/{tag}",
            t.seconds / (2 * trials * rounds) * 1e6,
            f"p_success@r{rounds}: rctree={bad[-1]:.2f} ftr={good[-1]:.2f}"))
    save_artifact("fig10_rctree", artifact)
    return rows
