"""CI guard: the deterministic plan values of a quick planning run must be
bitwise identical to the checked-in golden.

Usage (after ``python -m benchmarks.run --quick --seed 0 --modules planning``):

    python benchmarks/check_planning_golden.py

Compares the ``plans`` section of ``BENCH_planning.json`` (per-point
norm_time / norm_traffic / time_s for fig6/7/8; no wall-time fields)
against ``benchmarks/golden/planning_quick_seed0.json``.  Any diff means an
engine refactor changed the *plans*, not just their speed — that must be a
deliberate, golden-regenerating change, never a silent one.  The exact
witness oracle is what makes this pin possible: the old per-trial HiGHS
witness carried solver-internal vertex choices that were not guaranteed
reproducible across scipy builds.
"""
from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO_ROOT, "benchmarks", "golden",
                      "planning_quick_seed0.json")
CURRENT = os.path.join(REPO_ROOT, "BENCH_planning.json")


def _leaves(prefix: str, node):
    if isinstance(node, dict):
        for key in sorted(node):
            yield from _leaves(f"{prefix}.{key}", node[key])
    elif isinstance(node, list):
        for i, item in enumerate(node):
            yield from _leaves(f"{prefix}[{i}]", item)
    else:
        yield prefix, node


def main() -> int:
    with open(GOLDEN) as f:
        golden = json.load(f)
    with open(CURRENT) as f:
        got = json.load(f)
    for key in ("quick", "seed"):
        if got.get(key) != golden[key]:
            print(f"FAIL: run {key}={got.get(key)!r} does not match the "
                  f"golden's {key}={golden[key]!r}; run "
                  f"`python -m benchmarks.run --quick --seed {golden['seed']}"
                  f" --modules planning` first")
            return 1
    want = dict(_leaves("plans", golden["plans"]))
    have = dict(_leaves("plans", got.get("plans", {})))
    missing = [k for k in want if k not in have]
    diffs = [(k, want[k], have[k]) for k in want
             if k in have and have[k] != want[k]]
    if missing:
        print(f"FAIL: {len(missing)} golden values missing from this run "
              f"(first: {missing[0]})")
    for k, w, h in diffs[:20]:
        print(f"FAIL: {k}: golden {w!r} != got {h!r}")
    if missing or diffs:
        print(f"planning golden guard: {len(diffs)} diffs, "
              f"{len(missing)} missing of {len(want)} values")
        return 1
    print(f"planning golden guard OK: {len(want)} values bitwise equal")
    return 0


if __name__ == "__main__":
    sys.exit(main())
