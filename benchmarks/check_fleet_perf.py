"""CI perf-regression tripwire for the fleet event loop.

Usage (after ``python -m benchmarks.fleet_scale --quick``):

    python benchmarks/check_fleet_perf.py [--mode warn|fail] [--threshold 2.0]

Compares the ``us_per_event`` rows of ``BENCH_fleet.json``'s ``perf``
section against ``benchmarks/golden/fleet_perf_baseline.json`` and flags
any row slower than ``threshold`` x its *machine-normalized* baseline:
per-row ratios are divided by the median ratio across rows (the
machine-speed factor), so a uniformly slower CI runner never trips, while
one row that regressed relative to its row-mates — e.g. a change that
silently reintroduces a full-rescan recompute in the sharing engine —
does.  This is the guard the ISSUE 8 event-loop speedup lives behind: the
baseline pins the incremental-engine throughput, so drifting back toward
the PR-7 full-rescan numbers (also recorded per row in the perf section,
as ``pr7_us_per_event``) trips long before the speedup is gone.

``--mode warn`` (pull requests) prints GitHub warning annotations and
exits 0; ``--mode fail`` (pushes to main) exits 1 on any tripped row.
The old/new table is appended to ``$GITHUB_STEP_SUMMARY`` when set.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO_ROOT, "benchmarks", "golden",
                        "fleet_perf_baseline.json")
CURRENT = os.path.join(REPO_ROOT, "BENCH_fleet.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("warn", "fail"), default="warn")
    ap.add_argument("--threshold", type=float, default=2.0)
    args = ap.parse_args()

    with open(BASELINE) as f:
        base = json.load(f)
    with open(CURRENT) as f:
        got = json.load(f)
    perf = got.get("perf", {})

    ratios = {}
    missing = []
    for name, old in sorted(base["rows"].items()):
        new = perf.get(name, {}).get("us_per_event")
        if new is None:
            missing.append(name)
        else:
            ratios[name] = new / old if old > 0 else float("inf")
    finite = sorted(r for r in ratios.values() if r != float("inf"))
    # machine-speed factor: the median ratio.  A uniformly faster/slower
    # runner moves every row by the same factor; regressions stick out as
    # rows far above it.
    speed = finite[len(finite) // 2] if finite else 1.0

    lines = [f"machine-speed factor (median ratio): {speed:.2f}x", "",
             "| row | baseline us/ev | now us/ev | ratio | vs median | |",
             "|---|---:|---:|---:|---:|---|"]
    tripped = [(name, base["rows"][name], float("nan"), float("nan"))
               for name in missing]
    for name in missing:
        lines.append(f"| {name} | {base['rows'][name]:.1f} | MISSING | | "
                     f"| :boom: |")
    for name, ratio in sorted(ratios.items()):
        old = base["rows"][name]
        new = perf[name]["us_per_event"]
        rel = ratio / speed if speed > 0 else float("inf")
        slow = rel > args.threshold
        if slow:
            tripped.append((name, old, new, rel))
        lines.append(f"| {name} | {old:.1f} | {new:.1f} | {ratio:.2f}x | "
                     f"{rel:.2f}x | {':warning:' if slow else ''} |")
    table = "\n".join(lines)
    print(table)

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(f"### fleet perf tripwire ({args.mode}, "
                    f"{args.threshold:g}x)\n\n{table}\n")

    if not tripped:
        print(f"fleet perf tripwire OK: {len(base['rows'])} rows within "
              f"{args.threshold:g}x of the machine-normalized baseline")
        return 0
    for name, old, new, rel in tripped:
        if math.isnan(new):
            msg = (f"{name}: baseline row ({old:.1f} us/ev) missing from "
                   f"this run's BENCH_fleet.json perf section")
        else:
            msg = (f"{name}: {old:.1f} -> {new:.1f} us/ev "
                   f"({rel:.2f}x > {args.threshold:g}x the machine-"
                   f"normalized baseline)")
        if args.mode == "warn":
            print(f"::warning title=fleet perf tripwire::{msg}")
        else:
            print(f"::error title=fleet perf tripwire::{msg}")
    print(f"fleet perf tripwire: {len(tripped)} row(s) tripped")
    return 1 if args.mode == "fail" else 0


if __name__ == "__main__":
    sys.exit(main())
