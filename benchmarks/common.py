"""Shared benchmark plumbing: rows, timing, artifact JSON, run metadata."""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Version of the *envelope* of the root BENCH_*.json summaries (the
# schema_version / meta header around the payload), bumped when a reader
# of those files would need to change.  v2 = strict JSON (no Infinity/NaN
# literals; non-finite floats serialize as null) + meta header.
BENCH_SCHEMA_VERSION = 2


def git_describe() -> Optional[str]:
    """``git describe --always --dirty --tags`` of the repo, or None when
    git is unavailable (e.g. an sdist run) — metadata only, never fatal."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:
        return None


def run_meta(seed: int, **extra: Any) -> Dict[str, Any]:
    """Self-description header for a benchmark summary: enough to say
    *which* code produced it and under what knobs, without timestamps
    (the summaries are bitwise-pinned by CI goldens)."""
    meta: Dict[str, Any] = {
        "seed": int(seed),
        "quick": quick_mode(),
        "git": git_describe(),
    }
    meta.update(extra)
    return meta


def save_artifact(name: str, data: Any) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def row(name: str, us_per_call: float, derived: str) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def timed_best_of(fn, reps: int = 2):
    """(result, best wall seconds) over ``reps`` runs of a deterministic
    ``fn`` — min-of-N is the standard noise-robust microbenchmark estimator
    (shared CPU containers easily show 2x run-to-run wall variance)."""
    best, result = None, None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return result, best


def quick_mode() -> bool:
    return os.environ.get("BENCH_QUICK", "0") == "1"
