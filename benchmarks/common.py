"""Shared benchmark plumbing: rows, timing, artifact JSON."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def save_artifact(name: str, data: Any) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def row(name: str, us_per_call: float, derived: str) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def timed_best_of(fn, reps: int = 2):
    """(result, best wall seconds) over ``reps`` runs of a deterministic
    ``fn`` — min-of-N is the standard noise-robust microbenchmark estimator
    (shared CPU containers easily show 2x run-to-run wall variance)."""
    best, result = None, None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return result, best


def quick_mode() -> bool:
    return os.environ.get("BENCH_QUICK", "0") == "1"
