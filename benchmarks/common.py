"""Shared benchmark plumbing: rows, timing, artifact JSON, run metadata."""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Version of the *envelope* of the root BENCH_*.json summaries (the
# schema_version / meta header around the payload), bumped when a reader
# of those files would need to change.  v2 = strict JSON (no Infinity/NaN
# literals; non-finite floats serialize as null) + meta header.
BENCH_SCHEMA_VERSION = 2


def _git_describe_now() -> Optional[str]:
    """``git describe --always --dirty --tags`` of the repo, or None when
    git is unavailable (e.g. an sdist run) — metadata only, never fatal."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:
        return None


# Resolved eagerly at import — i.e. before any benchmark module rewrites a
# git-TRACKED artifact (BENCH_*.json, benchmarks/artifacts/*.json).  The
# old call-at-summary-time behavior ran git *after* those writes, so even a
# perfectly clean CI checkout recorded "git": "...-dirty" in its own meta —
# the run dirtied the tree itself.  Capturing the state of the *code* that
# produced the run, not of the artifacts it wrote, is the whole point of
# the field.  benchmarks/check_engine_parity.py asserts non-dirty under CI.
_GIT_DESCRIBE_AT_IMPORT = _git_describe_now()


def git_describe() -> Optional[str]:
    """Git state of the checkout *as of benchmark start* (import time),
    before the run's own artifact writes can dirty the tree."""
    return _GIT_DESCRIBE_AT_IMPORT


def bench_engine() -> str:
    """Planning engine for the fig6/7/8 drivers (``BENCH_ENGINE`` /
    ``--engine``): "batched" (default — the golden-pinned configuration),
    "scalar", or "jax" for the jit-compiled tier.  Non-default engines are
    for A/B measurement; the golden plan values are only pinned for the
    default."""
    return os.environ.get("BENCH_ENGINE", "batched")


def run_meta(seed: int, **extra: Any) -> Dict[str, Any]:
    """Self-description header for a benchmark summary: enough to say
    *which* code produced it and under what knobs, without timestamps
    (the summaries are bitwise-pinned by CI goldens)."""
    meta: Dict[str, Any] = {
        "seed": int(seed),
        "quick": quick_mode(),
        "git": git_describe(),
    }
    meta.update(extra)
    return meta


def save_artifact(name: str, data: Any) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def row(name: str, us_per_call: float, derived: str) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def timed_best_of(fn, reps: int = 2):
    """(result, best wall seconds) over ``reps`` runs of a deterministic
    ``fn`` — min-of-N is the standard noise-robust microbenchmark estimator
    (shared CPU containers easily show 2x run-to-run wall variance)."""
    best, result = None, None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return result, best


def quick_mode() -> bool:
    return os.environ.get("BENCH_QUICK", "0") == "1"
