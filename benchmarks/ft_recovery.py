"""Beyond-paper table: checkpoint-shard recovery on the TPU-fleet topology.

Monte-Carlo over host failures in 2-pod recovery groups with background
traffic and stragglers: predicted regeneration time per scheme, speedup vs
uniform STAR, and planning latency — the deployment-shaped version of the
paper's Fig. 6/7 evaluation (DESIGN.md §3).

Planning dispatches through the unified planner API (``repro.core.plan`` /
``plan_many``) over every batched-capable scheme in the registry: all trial
overlays are sampled first, then each scheme plans the whole batch in one
call.  ``run(engine="scalar")`` keeps the original per-overlay loop as the
correctness oracle; the sampled overlay sequence is identical in both
modes, so the mean times agree to batched-vs-scalar precision (~1e-12).
"""
from __future__ import annotations

import random
import time

import numpy as np

from repro.core import (CodeParams, caps_tensor, plan, plan_many,
                        scheme_names)
from repro.ft import Fleet, FleetConfig, choose_providers

from .common import quick_mode, row, save_artifact

# every batched-capable scheme in the registry (star/fr/tr/ftr/shah today;
# the next registered scheme joins the table with no edit here)
SCHEMES = scheme_names(batched=True)


def run(engine: str = "batched"):
    if engine not in ("batched", "scalar"):
        raise ValueError(f"unknown engine {engine!r}")
    quick = quick_mode()
    trials = 10 if quick else 60
    params = CodeParams(n=8, k=4, d=6, M=64.0, alpha=16.0)
    results = {"engine": engine}
    for frac, tag in ((0.0, "healthy"), (0.15, "stragglers")):
        fleet = Fleet(FleetConfig(num_pods=2, hosts_per_pod=16,
                                  straggler_fraction=frac), seed=1)
        rng = random.Random(2)
        overlays = []
        for _ in range(trials):
            group = rng.sample(range(fleet.num_hosts), params.n)
            failed = rng.choice(group)
            survivors = [h for h in group if h != failed]
            providers = choose_providers(fleet, survivors, failed, params.d,
                                         rng=rng)
            overlays.append(fleet.snapshot_overlay(failed, providers,
                                                   block_mb=64.0, rng=rng))
        acc = {s: 0.0 for s in SCHEMES}
        plan_ms = {s: 0.0 for s in SCHEMES}
        if engine == "batched":
            caps = caps_tensor(overlays)
            for name in SCHEMES:
                t0 = time.perf_counter()
                res = plan_many(caps, params, name, engine="batched")
                plan_ms[name] = (time.perf_counter() - t0) * 1e3
                acc[name] = float(np.sum(res.times))
        else:
            for overlay in overlays:
                for name in SCHEMES:
                    t0 = time.perf_counter()
                    p = plan(overlay, params, name, engine="scalar")
                    plan_ms[name] += (time.perf_counter() - t0) * 1e3
                    acc[name] += p.time
        results[tag] = {s: acc[s] / trials for s in SCHEMES}
        results[tag + "_plan_ms"] = {s: plan_ms[s] / trials for s in SCHEMES}
    save_artifact("ft_recovery", results)
    rows = []
    for tag in ("healthy", "stragglers"):
        r = results[tag]
        rows.append(row(
            f"ft_recovery/{tag}",
            results[tag + "_plan_ms"]["ftr"] * 1e3,
            " ".join(f"{s}={r[s]:.4f}s" for s in SCHEMES)
            + f" speedup_ftr={r['star'] / r['ftr']:.2f}x"))
    return rows
