"""CI guard: the fleet default path reproduces the checked-in golden bitwise.

Usage:

    python benchmarks/check_fleet_golden.py

Unlike ``check_planning_golden.py`` this guard does not diff a previously
written BENCH file: it re-simulates every configuration pinned in
``benchmarks/golden/fleet_quick_seed0.json`` fresh (they are quick-mode
rows, cheap by construction) and asserts two things:

* every golden configuration's ``Scenario`` carries ALL lifecycle and
  robustness knobs at their defaults — the golden pins the *default* path
  (pre-PR-3 dynamics, no estimate error, no brownouts, no watchdog, no
  degraded-d), so a knob leaking into those rows is itself the bug, not a
  reason to regenerate;
* each fresh summary equals the golden row bitwise over the *union* of
  keys, so a summary key added to ``FleetMetrics`` without regenerating
  the golden fails here instead of drifting silently.

Any diff means a simulator change altered the default-path dynamics —
that must be a deliberate, golden-regenerating change, never a silent one.
"""
from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO_ROOT, "benchmarks", "golden",
                      "fleet_quick_seed0.json")
sys.path.insert(0, REPO_ROOT)
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

# every Scenario knob that changes fleet dynamics when flipped on; the
# golden rows must carry all of them at these (inert) defaults
ROBUSTNESS_DEFAULTS = {
    "carryover": False,
    "migration": False,
    "bank_aware_migration": False,
    "estimate_noise": 0.0,
    "estimate_refresh_period": 0.0,
    "degrade_rate": 0.0,
    "degrade_mean_duration": 0.0,
    "degrade_lo": 0.0,
    "degrade_hi": 0.0,
    "degradations": (),
    "watchdog_period": 0.0,
    "degraded_d": False,
    "trace": False,
    # coded data plane (ISSUE 10): off, no arrival trace, no decode checks
    "dataplane": False,
    "read_trace": None,
    "dataplane_verify": False,
}


def main() -> int:
    import benchmarks.fleet_scale as fs
    from repro.fleet import make_policy, simulate
    from repro.obs import json_sanitize

    def _reject(const):  # the golden is strict JSON; Infinity/NaN is a bug
        raise ValueError(f"non-strict JSON literal {const} in {GOLDEN}")

    with open(GOLDEN) as f:
        golden = json.load(f, parse_constant=_reject)
    sweep = {name: (sc, pol) for name, sc, pol in fs._sweep(quick=True)}
    params = fs._params()
    problems = 0
    for name, expect in golden["configs"].items():
        if name not in sweep:
            print(f"FAIL: golden config {name} missing from the quick sweep")
            problems += 1
            continue
        sc, pol = sweep[name]
        for knob, default in ROBUSTNESS_DEFAULTS.items():
            if getattr(sc, knob) != default:
                print(f"FAIL: {name}: golden row has {knob}="
                      f"{getattr(sc, knob)!r}, want default {default!r}")
                problems += 1
        # sanitize like the writer does: the golden stores non-finite
        # floats (quiet rows' mttdl_estimate) as null since schema v2
        got = json_sanitize(simulate(
            sc, make_policy(pol), params,
            seed=fs._config_seed(golden["root_seed"], name)))
        for key in sorted(set(expect) | set(got)):
            if key not in expect:
                print(f"FAIL: {name}.{key}: new summary key not in golden "
                      f"(regenerate the golden deliberately)")
                problems += 1
            elif got.get(key) != expect[key]:
                print(f"FAIL: {name}.{key}: golden {expect[key]!r} "
                      f"!= got {got.get(key)!r}")
                problems += 1
    if problems:
        print(f"fleet golden guard: {problems} problems across "
              f"{len(golden['configs'])} configs")
        return 1
    n_vals = sum(len(v) for v in golden["configs"].values())
    print(f"fleet golden guard OK: {len(golden['configs'])} configs, "
          f"{n_vals} values bitwise equal")
    return 0


if __name__ == "__main__":
    sys.exit(main())
