"""Fig. 7: effect of bandwidth variance (n=20, k=5, d=10, M=1GB, MSR).

Paper claims: ~90% reduction for U1[0.3,120]; at tight distributions
(U4, U5) TR degenerates to STAR but FTR still saves 10-20%.
"""
from __future__ import annotations

from repro.core import CodeParams, scheme_names
from repro.storage import FIG7_DISTRIBUTIONS, compare_schemes

from .common import (bench_engine, quick_mode, row, save_artifact,
                     timed_best_of)

N, K, D, M_BLOCKS = 20, 5, 10, 8000.0
SCHEMES = scheme_names(batched=True)   # registry-driven scheme column


def run():
    quick = quick_mode()
    trials = 80 if quick else 120   # batched engine affords big batches
    p = CodeParams.msr(n=N, k=K, d=D, M=M_BLOCKS)
    rows, artifact = [], {"params": {"n": N, "k": K, "d": D, "M": M_BLOCKS,
                                     "trials": trials}, "points": []}
    engine = bench_engine()
    # untimed warm-up: one-time initialization out of the first row (at the
    # timed batch size under jax — one executable per (batch, d) shape)
    compare_schemes(p, next(iter(FIG7_DISTRIBUTIONS.values())), SCHEMES,
                    trials if engine == "jax" else 2, seed=0, engine=engine)
    for dist_name, sampler in FIG7_DISTRIBUTIONS.items():
        stats, secs = timed_best_of(
            lambda: compare_schemes(p, sampler, SCHEMES, trials, seed=7,
                                    engine=engine))
        point = {"distribution": dist_name}
        for s in SCHEMES:
            st = stats[s]
            point[s] = {"norm_time": st.mean_norm_time,
                        "norm_traffic": st.mean_norm_traffic,
                        "plan_ms": st.plan_seconds * 1e3}
        artifact["points"].append(point)
        rows.append(row(
            f"fig7/{dist_name}",
            secs / (trials * len(SCHEMES)) * 1e6,
            "norm_time " + " ".join(
                f"{s}={stats[s].mean_norm_time:.3f}" for s in SCHEMES)))
    save_artifact("fig7_bandwidth", artifact)
    return rows
