"""Fig. 7: effect of bandwidth variance (n=20, k=5, d=10, M=1GB, MSR).

Paper claims: ~90% reduction for U1[0.3,120]; at tight distributions
(U4, U5) TR degenerates to STAR but FTR still saves 10-20%.
"""
from __future__ import annotations

from repro.core import CodeParams
from repro.storage import FIG7_DISTRIBUTIONS, compare_schemes

from .common import Timer, quick_mode, row, save_artifact

N, K, D, M_BLOCKS = 20, 5, 10, 8000.0
SCHEMES = ("star", "fr", "tr", "ftr")


def run():
    quick = quick_mode()
    trials = 5 if quick else 30
    p = CodeParams.msr(n=N, k=K, d=D, M=M_BLOCKS)
    rows, artifact = [], {"params": {"n": N, "k": K, "d": D, "M": M_BLOCKS,
                                     "trials": trials}, "points": []}
    for dist_name, sampler in FIG7_DISTRIBUTIONS.items():
        with Timer() as t:
            stats = compare_schemes(p, sampler, SCHEMES, trials, seed=7)
        point = {"distribution": dist_name}
        for s in SCHEMES:
            st = stats[s]
            point[s] = {"norm_time": st.mean_norm_time,
                        "norm_traffic": st.mean_norm_traffic}
        artifact["points"].append(point)
        rows.append(row(
            f"fig7/{dist_name}",
            t.seconds / (trials * len(SCHEMES)) * 1e6,
            "norm_time " + " ".join(
                f"{s}={stats[s].mean_norm_time:.3f}" for s in SCHEMES)))
    save_artifact("fig7_bandwidth", artifact)
    return rows
