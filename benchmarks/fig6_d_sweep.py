"""Fig. 6: effect of the number of providers d (n=20, k=5, M=1GB, MSR,
capacities U[10,120] Mbps).

Paper claims (Section VI-A): FR/TR/FTR reduce regeneration time by 50-70%
vs STAR in most cases; FTR <= min(FR, TR) everywhere; FR beats TR at large
d and vice versa at small d; tree schemes consume more total bandwidth.
"""
from __future__ import annotations

from repro.core import CodeParams, scheme_names
from repro.storage import compare_schemes, uniform

from .common import (bench_engine, quick_mode, row, save_artifact,
                     timed_best_of)

N, K, M_BLOCKS = 20, 5, 8000.0  # 1 GB in 1-Mb blocks
# registry-driven: every scheme with a batched planner (star/fr/tr/ftr +
# the shah baseline; rctree stays out, as in the paper's Fig. 6)
SCHEMES = scheme_names(batched=True)


def run():
    quick = quick_mode()
    # the batched planning engine (repro.core.batched) makes large Monte-
    # Carlo batches cheaper than the seed's 5 scalar trials were
    trials = 80 if quick else 120
    ds = [6, 10, 15, 19] if quick else list(range(K + 1, N))
    rows, artifact = [], {"params": {"n": N, "k": K, "M": M_BLOCKS,
                                     "trials": trials}, "points": []}
    engine = bench_engine()
    # untimed warm-up: numpy/scipy one-time initialization out of row 1.
    # The jax engine compiles one executable per (batch, d) shape, so its
    # warm-up must visit every d at the *timed* batch size — compilation
    # is a one-time cost and stays out of the measured rows.
    for d in ds if engine == "jax" else ds[:1]:
        compare_schemes(CodeParams.msr(n=N, k=K, d=d, M=M_BLOCKS), uniform(),
                        SCHEMES, trials if engine == "jax" else 2, seed=0,
                        engine=engine)
    for d in ds:
        p = CodeParams.msr(n=N, k=K, d=d, M=M_BLOCKS)
        stats, secs = timed_best_of(
            lambda: compare_schemes(p, uniform(), SCHEMES, trials,
                                    seed=42 + d, engine=engine))
        point = {"d": d}
        for s in SCHEMES:
            st = stats[s]
            point[s] = {"norm_time": st.mean_norm_time,
                        "norm_traffic": st.mean_norm_traffic,
                        "time_s": st.mean_time,
                        "plan_ms": st.plan_seconds * 1e3}
        artifact["points"].append(point)
        rows.append(row(
            f"fig6/d={d}",
            secs / (trials * len(SCHEMES)) * 1e6,
            "norm_time " + " ".join(
                f"{s}={stats[s].mean_norm_time:.3f}" for s in SCHEMES)))
    save_artifact("fig6_d_sweep", artifact)
    return rows
