"""CI guard for the fleet flight-recorder artifacts (ISSUE 7).

Usage (after ``python -m benchmarks.fleet_scale --quick --seed 0 --trace``):

    python benchmarks/check_trace.py

For every ``<name>.jsonl`` / ``<name>.trace.json`` pair under
``benchmarks/artifacts/traces/`` this checks:

* both files are *strict* JSON (no ``Infinity``/``NaN`` literals — the
  parser rejects them explicitly);
* the JSONL header carries the expected ``schema_version`` and ``kind``,
  and every following line parses as one event with a ``t``/``ev`` pair;
* the Chrome trace has well-formed ``traceEvents`` (every event carries
  ``ph``/``pid``/``ts``; begin/end spans are balanced per (cat, id));
* the span-count contract: finished ``transfer`` spans (reason complete or
  abort) equal ``completed + aborted`` from the recorder's embedded
  summary — every repair the metrics counted left a matching span;
* link-time conservation: integrated per-link user-seconds are at least
  ``completed * regen_mean`` (each active repair holds >= 1 link for its
  whole transfer window, so total link occupancy bounds total repair time
  from above);
* where a config name also appears in the quick golden
  (``benchmarks/golden/fleet_quick_seed0.json``), the recorder's embedded
  summary equals the golden row bitwise — the flight recorder observed the
  *same* simulation the untraced default path pins.
"""
from __future__ import annotations

import glob
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_DIR = os.path.join(REPO_ROOT, "benchmarks", "artifacts", "traces")
GOLDEN = os.path.join(REPO_ROOT, "benchmarks", "golden",
                      "fleet_quick_seed0.json")
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

CHROME_REQUIRED = ("ph", "pid", "ts")


def _strict_load(path: str):
    def _reject(const):
        raise ValueError(f"non-strict JSON literal {const} in {path}")

    with open(path) as f:
        return json.load(f, parse_constant=_reject)


def _check_jsonl(path: str, problems: list):
    from repro.obs import SCHEMA_VERSION, TRACE_KIND

    def _reject(const):
        raise ValueError(f"non-strict JSON literal {const} in {path}")

    with open(path) as f:
        lines = [json.loads(ln, parse_constant=_reject)
                 for ln in f if ln.strip()]
    if not lines:
        problems.append(f"{path}: empty")
        return None, []
    header, events = lines[0], lines[1:]
    if header.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"{path}: schema_version "
                        f"{header.get('schema_version')!r}, "
                        f"want {SCHEMA_VERSION}")
    if header.get("kind") != TRACE_KIND:
        problems.append(f"{path}: kind {header.get('kind')!r}, "
                        f"want {TRACE_KIND!r}")
    if header.get("events") != len(events):
        problems.append(f"{path}: header says {header.get('events')} "
                        f"events, file has {len(events)}")
    for i, ev in enumerate(events):
        if "t" not in ev or "ev" not in ev:
            problems.append(f"{path}: line {i + 2} missing t/ev")
            break
    return header, events


def _check_chrome(path: str, header: dict, problems: list) -> int:
    """Validate the Chrome trace; return the finished-transfer span count."""
    trace = _strict_load(path)
    if "traceEvents" not in trace:
        problems.append(f"{path}: no traceEvents")
        return 0
    open_spans = {}
    finished_transfers = 0
    for ev in trace["traceEvents"]:
        for key in CHROME_REQUIRED:
            if key not in ev:
                problems.append(f"{path}: event missing {key!r}: {ev!r}")
                return finished_transfers
        if ev["ph"] == "b":
            open_spans[(ev.get("cat"), ev.get("id"))] = ev
        elif ev["ph"] == "e":
            if open_spans.pop((ev.get("cat"), ev.get("id")), None) is None:
                problems.append(f"{path}: end without begin: {ev!r}")
            if (ev.get("cat") == "repair"
                    and ev.get("args", {}).get("reason")
                    in ("complete", "abort")):
                finished_transfers += 1
    if open_spans:
        problems.append(f"{path}: {len(open_spans)} unclosed spans "
                        f"(chrome_trace must close them at last_ts)")
    return finished_transfers


def main() -> int:
    jsonl_paths = sorted(glob.glob(os.path.join(TRACE_DIR, "*.jsonl")))
    if not jsonl_paths:
        print(f"FAIL: no traces under {TRACE_DIR} "
              f"(run benchmarks.fleet_scale with --trace first)")
        return 1
    golden_configs = {}
    if os.path.exists(GOLDEN):
        golden_configs = _strict_load(GOLDEN).get("configs", {})
    problems: list = []
    golden_hits = 0
    for jsonl_path in jsonl_paths:
        name = os.path.basename(jsonl_path)[:-len(".jsonl")]
        header, events = _check_jsonl(jsonl_path, problems)
        if header is None:
            continue
        meta = header.get("meta") or {}
        summary = meta.get("summary") or {}
        links = meta.get("links") or {}
        chrome_path = os.path.join(TRACE_DIR, f"{name}.trace.json")
        if not os.path.exists(chrome_path):
            problems.append(f"{name}: missing {chrome_path}")
            continue
        finished = _check_chrome(chrome_path, header, problems)
        # span-count contract (skip when the ring buffer dropped events:
        # early begins may be gone, so the count is legitimately short)
        want = summary.get("completed", 0) + summary.get("aborted", 0)
        if header.get("dropped", 0) == 0 and finished != want:
            problems.append(
                f"{name}: {finished} finished transfer spans != "
                f"completed+aborted = {want}")
        # link-time conservation: every active repair occupies >= 1 link
        # for its whole window, so summed user-seconds bound total repair
        # seconds from above
        total_user_seconds = links.get("total_user_seconds", 0.0)
        lower = (summary.get("completed", 0)
                 * summary.get("regen_mean", 0.0))
        if total_user_seconds < lower * (1 - 1e-9):
            problems.append(
                f"{name}: link user-seconds {total_user_seconds:.3f} < "
                f"completed*regen_mean {lower:.3f} (conservation violated)")
        # the recorder's embedded summary must match the untraced golden
        if name in golden_configs:
            golden_hits += 1
            expect = golden_configs[name]
            for key in sorted(set(expect) | set(summary)):
                if summary.get(key) != expect.get(key):
                    problems.append(
                        f"{name}.{key}: traced summary "
                        f"{summary.get(key)!r} != golden "
                        f"{expect.get(key)!r}")
    if problems:
        for p in problems:
            print(f"FAIL: {p}")
        print(f"trace guard: {len(problems)} problems across "
              f"{len(jsonl_paths)} traces")
        return 1
    print(f"trace guard OK: {len(jsonl_paths)} traces valid "
          f"({golden_hits} cross-checked against the fleet golden)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
