"""Lockstep multi-cluster ensemble (ISSUE 8): parity, pooling, CIs.

Three contracts:

* **member parity** — interleaving K simulators through the lockstep
  heap must not perturb any of them: every member's metrics equal the
  solo ``run()`` at the same derived seed, bitwise;
* **pooling closed forms** — ``pool_metrics`` sums time integrals /
  counters and concatenates samples, so the pooled ``summary()`` ratios
  have hand-computable values on crafted members;
* **bootstrap CIs** — deterministic in the seed, bracket the point
  estimate, and collapse to zero width on an ensemble of identical
  members (every resample is the same multiset).
"""
import math

import pytest

from repro.core import CodeParams
from repro.fleet import (ClusterEnsemble, FleetMetrics, FleetSimulator,
                         Scenario, bootstrap_cis, cluster_seed,
                         make_policy, pool_metrics)
from repro.fleet.scenario import uniform_matrix

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)


def _scenario(duration=200.0):
    return Scenario(num_nodes=20, duration=duration, failure_rate=1e-2,
                    capacity_model=uniform_matrix(0.3, 6.0),
                    max_concurrent=6, read_rate=0.5, read_duration=15.0)


def test_members_match_solo_runs_bitwise():
    sc = _scenario()
    ens = ClusterEnsemble(sc, lambda: make_policy("star"), PARAMS,
                          clusters=3, root_seed=11)
    members = ens.run()
    assert len(members) == 3
    for k, m in enumerate(members):
        solo = FleetSimulator(sc, make_policy("star"), PARAMS,
                              seed=cluster_seed(11, k)).run()
        assert m.summary() == solo.summary(), f"member {k} diverged"


def test_cluster_seed_distinct_and_stable():
    seeds = [cluster_seed(5, k) for k in range(64)]
    assert len(set(seeds)) == 64
    # member k's trajectory is independent of ensemble size
    assert cluster_seed(5, 3) == seeds[3]
    assert all(0 <= s < (1 << 31) for s in seeds)


def _crafted(now, backlog_integral, completed, regen, max_backlog,
             expected_losses=0.0):
    m = FleetMetrics(n=8, k=2, failure_rate=1e-3)
    m.now = now
    m.backlog_integral = backlog_integral
    m.completed = completed
    m.regen_times = list(regen)
    m.max_backlog = max_backlog
    m.expected_losses = expected_losses
    return m


def test_pooling_closed_forms():
    a = _crafted(now=10.0, backlog_integral=20.0, completed=2,
                 regen=[1.0, 3.0], max_backlog=4, expected_losses=0.5)
    b = _crafted(now=30.0, backlog_integral=30.0, completed=3,
                 regen=[5.0, 7.0, 9.0], max_backlog=2,
                 expected_losses=1.5)
    s = pool_metrics([a, b]).summary()
    assert s["duration"] == 40.0                     # durations sum
    assert s["mean_backlog"] == 50.0 / 40.0          # Σ∫b dt / Σdur
    assert s["completed"] == 5                       # counters sum
    assert s["max_backlog"] == 4                     # high-water mark: max
    assert s["regen_mean"] == 5.0                    # concat then mean
    assert s["regen_p50"] == 5.0
    assert s["expected_data_losses"] == 2.0
    assert s["mttdl_estimate"] == 40.0 / 2.0         # Σdur / ΣE[losses]


def test_pooling_zero_losses_gives_inf_mttdl():
    a = _crafted(10.0, 0.0, 0, [], 0)
    s = pool_metrics([a, a]).summary()
    assert s["mttdl_estimate"] == math.inf


def test_pool_empty_rejected():
    with pytest.raises(ValueError):
        pool_metrics([])
    with pytest.raises(ValueError):
        bootstrap_cis([], ["mean_backlog"])


def test_identical_members_zero_width_ci():
    m = FleetSimulator(_scenario(), make_policy("star"), PARAMS,
                       seed=cluster_seed(2, 0)).run()
    cis = bootstrap_cis([m, m, m, m], ["mean_backlog", "regen_p50"],
                        n_boot=50, seed=9)
    for lo, point, hi in cis.values():
        assert lo == point == hi


def test_bootstrap_deterministic_and_brackets_point():
    sc = _scenario()
    ens = ClusterEnsemble(sc, lambda: make_policy("star"), PARAMS,
                          clusters=4, root_seed=13)
    members = ens.run()
    keys = ["mean_backlog", "regen_p50", "unavail_fraction"]
    a = bootstrap_cis(members, keys, n_boot=80, seed=1)
    b = bootstrap_cis(members, keys, n_boot=80, seed=1)
    c = bootstrap_cis(members, keys, n_boot=80, seed=2)
    assert a == b                          # seeded: bitwise repeatable
    assert a != c                          # and the seed actually matters
    for lo, point, hi in a.values():
        assert lo <= hi
        assert math.isfinite(point)
    # pooled point estimate == pooling by hand
    assert a["mean_backlog"][1] == pool_metrics(members).summary()[
        "mean_backlog"]


def test_ensemble_pooled_and_cis_lazy_run():
    """`pooled()` / `cis()` before `run()` drive the ensemble once."""
    ens = ClusterEnsemble(_scenario(120.0), lambda: make_policy("star"),
                          PARAMS, clusters=2, root_seed=3)
    pooled = ens.pooled()
    assert ens.members is not None
    assert pooled.now == sum(m.now for m in ens.members)


def test_ensemble_rejects_empty():
    with pytest.raises(ValueError):
        ClusterEnsemble(_scenario(), lambda: make_policy("star"), PARAMS,
                        clusters=0)
