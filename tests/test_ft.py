"""Fault-tolerance layer: erasure-coded checkpoint save / fail / regenerate /
restore round-trips on real pytrees, elastic resharding, straggler response."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.ft import (ECCheckpoint, ErasureCoder, Fleet, FleetConfig,
                      bytes_to_tree, tree_to_bytes)


def make_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.normal(size=(32, 16)).astype(np.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.bfloat16)},
        "opt": {"m": rng.normal(size=(32, 16)).astype(np.float32),
                "step": np.int32(123)},
    }


def trees_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_tree_bytes_roundtrip():
    state = make_state()
    buf, spec = tree_to_bytes(state)
    assert trees_equal(state, bytes_to_tree(buf, spec))


def make_ckpt(seed=0, n=8, k=4, d=6):
    fleet = Fleet(FleetConfig(num_pods=2, hosts_per_pod=8), seed=seed)
    coder = ErasureCoder(n=n, k=k, d=d, blocks_per_host=8, seed=seed)
    ckpt = ECCheckpoint(fleet, coder, hosts=list(range(n)), seed=seed)
    state = make_state(seed)
    ckpt.save(state, step=7)
    return fleet, ckpt, state


def test_save_restore_any_k():
    _, ckpt, state = make_ckpt()
    for hosts in ([0, 1, 2, 3], [4, 5, 6, 7], [1, 3, 5, 7]):
        assert trees_equal(state, ckpt.restore(hosts))


@pytest.mark.parametrize("scheme", ["star", "fr", "tr", "ftr", "auto"])
def test_failure_regeneration(scheme):
    _, ckpt, state = make_ckpt(seed=3)
    log = ckpt.on_host_failure(2, scheme=scheme)
    assert log.report.regenerated_host == 2
    assert np.isfinite(log.decision.predicted_s)
    # after regeneration, any k hosts including the newcomer still restore
    assert trees_equal(state, ckpt.restore([2, 4, 6, 7]))
    assert trees_equal(state, ckpt.restore([0, 1, 2, 5]))


def test_repeated_failures_preserve_mds():
    _, ckpt, state = make_ckpt(seed=5)
    for failed in (1, 6, 3, 1, 0):
        ckpt.on_host_failure(failed, scheme="ftr")
    assert trees_equal(state, ckpt.restore([0, 1, 3, 6]))
    assert trees_equal(state, ckpt.restore([2, 4, 5, 7]))


def test_ftr_beats_or_matches_star_prediction():
    _, ckpt, _ = make_ckpt(seed=9)
    log = ckpt.on_host_failure(4, scheme="auto")
    alts = log.decision.alternatives
    assert alts["ftr"] <= alts["star"] + 1e-9
    assert log.decision.predicted_s <= min(alts.values()) + 1e-9


def test_straggler_rerouting():
    """A straggling provider must carry less traffic under FR/FTR than its
    fair share."""
    fleet, ckpt, _ = make_ckpt(seed=11)
    # make host 1 a hard straggler and fail host 0
    fleet.straggle.clear()
    fleet.mark_straggler(1, 0.02)
    log = ckpt.on_host_failure(0, scheme="fr")
    decision = log.decision
    if 1 in decision.providers:
        i = decision.providers.index(1) + 1
        betas = decision.plan.betas
        fair = sum(betas) / len(betas)
        assert betas[i - 1] <= fair + 1e-9, (betas, i)


def test_elastic_reshard():
    fleet, ckpt, state = make_ckpt(seed=13)
    new_coder = ErasureCoder(n=6, k=3, d=4, blocks_per_host=8, seed=99)
    ck2 = ckpt.reshard(new_coder, new_hosts=[8, 9, 10, 11, 12, 13])
    assert trees_equal(state, ck2.restore([9, 11, 13]))
    ck2.on_host_failure(10, scheme="ftr")
    assert trees_equal(state, ck2.restore([8, 10, 12]))


def test_replacement_host_id():
    fleet, ckpt, state = make_ckpt(seed=17)
    log = ckpt.on_host_failure(5, replacement=15, scheme="ftr")
    assert 15 in ckpt.group.shards and 5 not in ckpt.group.shards
    assert trees_equal(state, ckpt.restore([15, 0, 1, 2]))
