"""GF arithmetic + RLNC data-plane tests (paper Section II-A)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.coding import GF, GF8, GF16, RLNC, CodedBlocks


@pytest.mark.parametrize("field", [GF8, GF16])
def test_field_axioms(field):
    rng = np.random.default_rng(0)
    a = field.random(512, rng).astype(np.int64)
    b = field.random(512, rng).astype(np.int64)
    c = field.random(512, rng).astype(np.int64)
    # commutativity / associativity / distributivity over XOR-addition
    np.testing.assert_array_equal(field.mul(a, b), field.mul(b, a))
    np.testing.assert_array_equal(field.mul(field.mul(a, b), c),
                                  field.mul(a, field.mul(b, c)))
    np.testing.assert_array_equal(field.mul(a, b ^ c),
                                  field.mul(a, b) ^ field.mul(a, c))
    # inverses
    nz = a[a != 0]
    np.testing.assert_array_equal(field.mul(nz, field.inv(nz)),
                                  np.ones_like(nz, dtype=field.dtype))


def test_gf8_generator_order():
    """2 must generate the full multiplicative group for 0x11D."""
    assert len(set(GF8.exp[:255].tolist())) == 255


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 16), st.integers(2, 16))
def test_solve_roundtrip(seed, n, m):
    rng = np.random.default_rng(seed)
    f = GF8
    while True:
        A = f.random((n, n), rng)
        if f.rank(A) == n:
            break
    X = f.random((n, m), rng)
    Y = f.matmul(A, X)
    np.testing.assert_array_equal(f.solve(A, Y), X)


def test_cauchy_mds():
    """Every square submatrix of a Cauchy matrix is invertible: any k nodes
    suffice — the MDS property by construction."""
    f = GF8
    C = f.cauchy_matrix(20, 10)
    rng = np.random.default_rng(3)
    for _ in range(20):
        rows = rng.choice(20, size=10, replace=False)
        assert f.rank(C[rows]) == 10


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rlnc_distribute_reconstruct(seed):
    """(n, k) distribution then reconstruction from random k nodes."""
    rng = np.random.default_rng(seed)
    n, k, M_blocks, blksz = 6, 3, 9, 16
    alpha = M_blocks // k
    rl = RLNC(GF8)
    file_blocks = GF8.random((M_blocks, blksz), rng)
    nodes = rl.distribute(file_blocks, n, alpha, rng)
    picks = rng.choice(n, size=k, replace=False)
    chosen = [nodes[i] for i in picks]
    if rl.can_reconstruct(chosen, M_blocks):  # whp over GF(2^8)
        got = rl.reconstruct(chosen, M_blocks)
        np.testing.assert_array_equal(got, file_blocks)


def test_rlnc_regeneration_star():
    """Regenerate a lost node via uniform star repair; file still decodable."""
    rng = np.random.default_rng(7)
    n, k, d = 5, 2, 4
    alpha, blksz = 4, 8
    M_blocks = k * alpha
    # MSR beta = alpha/(d-k+1) = 4/3; executor ceil-rounds to 2 (Section III-C)
    beta = 2
    rl = RLNC(GF8)
    file_blocks = GF8.random((M_blocks, blksz), rng)
    nodes = rl.distribute(file_blocks, n, alpha, rng)
    # node 4 dies; 0..3 send beta blocks each; newcomer stores alpha combos
    received = None
    for i in range(d):
        part = rl.encode(nodes[i], beta, rng)
        received = part if received is None else received.concat(part)
    newcomer = rl.regenerate(received, alpha, rng)
    survivors = [nodes[0], nodes[1], nodes[2], nodes[3], newcomer]
    ok = 0
    for a in range(len(survivors)):
        for b in range(a + 1, len(survivors)):
            if rl.can_reconstruct([survivors[a], survivors[b]], M_blocks):
                ok += 1
    # Uniform star repair at MSR with d = 4 >= needed: all pairs decode whp.
    assert ok >= 9, f"only {ok}/10 pairs decodable"


def test_kernel_backed_rlnc():
    """The full coding plane running through the Pallas kernel wrapper."""
    from repro.kernels.ops import gf_matmul_numpy
    rng = np.random.default_rng(11)
    rl = RLNC(GF8, matmul=gf_matmul_numpy)
    file_blocks = GF8.random((6, 32), rng)
    nodes = rl.distribute(file_blocks, 4, 3, rng)
    got = rl.reconstruct(nodes[:2], 6)
    np.testing.assert_array_equal(got, file_blocks)
