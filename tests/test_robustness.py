"""Plan-vs-reality robustness validated against closed forms (ISSUE 6).

* believed/true capacity split — predictions read the believed matrix and
  skip brownout multipliers, actual flow rates do the opposite;
* estimate staleness — a stale believed snapshot yields a closed-form
  plan-error sample, and the next refresh (which measures achieved rates,
  brownouts included) drives it back to zero;
* straggler/stall injection — deterministic brownouts slow a repair by the
  exact piecewise amount, a re-degrade supersedes the stale recovery via
  the generation counter, and the Poisson degrade clock never perturbs the
  other rng streams;
* watchdog mitigation ladder — lag-ratio flag then credited in-place
  replan (closed-form rescue at a capacity shock the frozen plan would
  crawl through), stall flag then eviction of the straggling provider with
  banked blocks carried over, and retry-budget exhaustion (give-up) when
  the only possible helper is the stalled one;
* graceful degradation — repairs admitted with d' in [k, d) helpers when
  fewer than d are healthy, instead of queueing forever;
* the drain-queue rollback regression (a provider-picker error mid-batch
  must not wedge slots in REPAIRING) and Scenario validation messages;
* the seeded stragglers acceptance: mitigation ON strictly improves mean
  backlog AND the p99 vulnerability window at the same seed.

The progress-vector conservation invariant (banked + outstanding == plan
total, PR 3) is asserted at every epoch of every closed-form simulation
here via ``_CheckedSim`` — eviction, watchdog replan, and degraded-d
re-admission all move banked work around and must not create or destroy
any.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import (CodeParams, OverlayNetwork, RepairPlan, plan_time,
                        tree_flows)
from repro.fleet import (FleetSimulator, FlexiblePolicy, LinkShareModel,
                         RepairPolicy, Scenario, mitigated, simulate,
                         stragglers)
from repro.fleet.cluster import FAILED, REPAIRING
from repro.fleet.sim import QueuedRepair

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)
CRAFT_PARAMS = CodeParams(n=6, k=2, d=2, M=2.0, alpha=1.0)


class CraftedRelayPolicy(RepairPolicy):
    """Fixed relay tree 1 -> 2 -> newcomer with unit betas: flow 1.0 on
    overlay edges (1, 2) and (2, 0), total 2.0 blocks per plan."""

    name = "crafted"

    def plan_batch(self, caps, params):
        plans = []
        for c in caps:
            parent = {1: 2, 2: 0}
            betas = [1.0, 1.0]
            flows = tree_flows(parent, betas, params.alpha)
            net = OverlayNetwork(c.tolist())
            plan = RepairPlan("crafted", params, parent, betas, flows, 0.0)
            plan.time = plan_time(plan, net)
            plans.append(plan)
        return plans


class CraftedBestOfPolicy(RepairPolicy):
    """Pick the faster of {relay 1 -> 2 -> 0, star} under the given caps."""

    name = "crafted_best"

    def plan_batch(self, caps, params):
        plans = []
        for c in caps:
            net = OverlayNetwork(c.tolist())
            cands = []
            for parent in ({1: 2, 2: 0}, {1: 0, 2: 0}):
                betas = [1.0, 1.0]
                flows = tree_flows(parent, betas, params.alpha)
                p = RepairPlan("crafted", params, parent, betas, flows, 0.0)
                p.time = plan_time(p, net)
                cands.append(p)
            plans.append(min(cands, key=lambda p: p.time))
        return plans


class _CheckedSim(FleetSimulator):
    """FleetSimulator asserting the progress-vector conservation invariant
    (banked + outstanding == plan total per current-plan edge) at every
    event epoch — across evictions, watchdog replans, and degraded-d
    re-admissions, credit transfer must neither create nor destroy work."""

    checks = 0

    def _advance(self, t):
        super()._advance(t)
        for r in self.active:
            for link, (banked, todo, total) in r.work_accounting().items():
                assert banked >= -1e-9 and todo >= -1e-9, (link, banked,
                                                           todo)
                assert abs(banked + todo - total) <= 1e-9 * max(1.0, total)
            _CheckedSim.checks += 1


def _flat_caps(n, c=10.0):
    caps = np.full((n, n), c)
    np.fill_diagonal(caps, 0.0)
    return caps, (lambda rng, m: caps.copy())


def _shared_pair_picker(failed, healthy, rng):
    return [4, 5]


# ---------------------------------------------------------------------------
# Believed vs true capacities in the share model
# ---------------------------------------------------------------------------

def test_share_model_splits_believed_and_true_views():
    caps = np.array([[0.0, 10.0], [10.0, 0.0]])
    believed = np.array([[0.0, 4.0], [4.0, 0.0]])
    m = LinkShareModel(caps, believed=believed)
    m.out_mult = np.array([0.5, 1.0])
    # actual rates: true caps x the source node's brownout multiplier
    assert m.true_cap((0, 1)) == pytest.approx(5.0)
    assert m.share((0, 1)) == pytest.approx(5.0)
    assert m.nominal_time([((0, 1), 1.0)]) == pytest.approx(0.2)
    # predictions: the believed matrix, blind to the brownout
    assert m.believed_cap((0, 1)) == pytest.approx(4.0)
    assert m.residual((0, 1)) == pytest.approx(4.0)
    assert m.admission_time([((0, 1), 1.0)]) == pytest.approx(0.25)
    assert m.residual_overlay([0, 1])[0, 1] == pytest.approx(4.0)
    # both views fall back to the true matrix when the machinery is off
    off = LinkShareModel(caps)
    assert off.true_cap((0, 1)) == off.believed_cap((0, 1)) == 10.0


# ---------------------------------------------------------------------------
# Straggler/stall injection: closed-form slowdown and recovery
# ---------------------------------------------------------------------------

def test_degrade_and_recover_closed_form():
    """All links 10 b/s; the relay plan (4 -> 5 -> 0, 1 block per edge)
    solo takes 0.1 s.  Node 5's outgoing rates are halved on [0, 0.15]:
    the (5, 0) edge runs at 5 b/s, so at recovery the repair is 75% done
    (0.15 of a 0.2 s nominal) and the remaining 25% takes 0.025 s at full
    rate — completion at exactly 0.175 s."""
    _, model = _flat_caps(6)
    sc = Scenario(num_nodes=6, duration=10.0, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  provider_picker=_shared_pair_picker,
                  degradations=((0.0, 5, 0.5, 0.15),))
    m = _CheckedSim(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 1 and m.aborted == 0
    assert m.degrade_events == 1
    assert m.regen_times[0] == pytest.approx(0.175, abs=1e-12)
    # the stale-monitoring plan promised 0.1 s; reality took 0.175
    assert m.plan_errors[0] == pytest.approx(0.75, abs=1e-9)


def test_redegrade_supersedes_stale_recovery():
    """A second brownout before the first one's recovery must win: the
    RECOVER event of generation 1 fires mid-generation-2 and is a no-op.
    Rates: 5 b/s on [0, 0.05] (factor 0.5), then 2.5 b/s (factor 0.25)
    until far past completion.  Work done at 0.05 is 25% of the 0.2 s
    nominal; the remaining 75% of the 0.4 s nominal takes 0.3 s —
    completion at 0.35 s.  (A wrongly-applied stale recovery would finish
    at 0.1625 s.)"""
    _, model = _flat_caps(6)
    sc = Scenario(num_nodes=6, duration=10.0, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  provider_picker=_shared_pair_picker,
                  degradations=((0.0, 5, 0.5, 0.1),
                                (0.05, 5, 0.25, 1000.0)))
    m = _CheckedSim(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 1 and m.degrade_events == 2
    assert m.regen_times[0] == pytest.approx(0.35, abs=1e-12)


def test_degrade_stream_independent_of_dynamics():
    """The Poisson degrade clock runs over all n slots at a constant rate,
    so the brownout sample path is identical whether or not the mitigation
    machinery reshapes the rest of the run — seeded A/B comparisons see
    the same faults."""
    sc = stragglers(16, duration=2000.0)
    a = simulate(sc, FlexiblePolicy(), PARAMS, seed=7)
    b = simulate(mitigated(sc), FlexiblePolicy(), PARAMS, seed=7)
    assert a["degrade_events"] == b["degrade_events"] > 0


# ---------------------------------------------------------------------------
# Estimate error: stale believed snapshots and the plan-error metric
# ---------------------------------------------------------------------------

def test_stale_estimates_closed_form_plan_error():
    """Node 5 browns out (factor 0.5) right after the believed snapshot at
    t=0: the first repair is planned and ETA'd against the stale matrix
    (predicted 0.1 s) but flows at true rates (realized 0.2 s) — plan
    error exactly +1.0.  The refresh at t=0.25 measures achieved rates
    (brownout included), so the second repair at t=0.3 is predicted at
    0.2 s and realizes 0.2 s — plan error exactly 0.0."""
    _, model = _flat_caps(6)
    sc = Scenario(num_nodes=6, duration=2.0, failure_rate=0.0,
                  failures=((0.0, 0), (0.3, 1)), capacity_model=model,
                  provider_picker=_shared_pair_picker,
                  degradations=((0.0, 5, 0.5, 1000.0),),
                  estimate_refresh_period=0.25)
    m = _CheckedSim(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 2
    assert sorted(m.regen_times) == [pytest.approx(0.2, abs=1e-12)] * 2
    assert m.plan_errors == [pytest.approx(1.0, abs=1e-9),
                             pytest.approx(0.0, abs=1e-9)]
    s = m.summary()
    assert s["plan_err_mean"] == pytest.approx(0.5, abs=1e-9)
    assert s["plan_err_p50"] == pytest.approx(0.5, abs=1e-9)


# ---------------------------------------------------------------------------
# Watchdog: lag flag -> credited in-place replan (closed form at a shock)
# ---------------------------------------------------------------------------

class _OneShockSim(_CheckedSim):
    """Deterministic shock at the first CAPACITY_SHOCK event: the relay
    link (4, 5) collapses and the direct link (4, 0) opens up."""

    def _capacity_shock(self):
        self.cluster.caps[4, 5] = 0.01
        self.cluster.caps[4, 0] = 100.0
        self._replan_pending = True


def test_watchdog_replan_rescues_lagging_repair():
    """Migration is OFF, so after the shock at t=0.005 guts (4, 5) the
    half-done relay plan would crawl its remaining 0.5 blocks at 0.01 b/s
    for ~50 s (pinned by the migration test in test_fleet.py).  The
    watchdog tick at t=0.01 sees progress ~0.5 of the predicted 1.0
    (lag 1.5 flags it), and its rescue replan — planned against the
    refreshed believed matrix — moves to the now-open star, credits the
    ~0.5 blocks banked on (5, 0), and finishes at t=0.02."""
    n = 6
    caps = np.full((n, n), 100.0)
    np.fill_diagonal(caps, 0.0)
    caps[4, 0] = 0.1                    # direct path closed pre-shock
    model = (lambda rng, m: caps.copy())
    sc = Scenario(num_nodes=n, duration=0.1, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  provider_picker=_shared_pair_picker,
                  shock_period=0.005, carryover=True,
                  estimate_refresh_period=0.002,
                  watchdog_period=0.01, watchdog_lag=1.5)
    m = _OneShockSim(sc, CraftedBestOfPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 1 and m.aborted == 0
    assert m.watchdog_flags == 1 and m.watchdog_replans == 1
    assert m.evictions == 0 and m.watchdog_giveups == 0
    assert m.vulnerability_windows[0] == pytest.approx(0.02, rel=1e-9)
    # ~0.5 blocks banked on (5, 0) credited against the 2-block star plan
    assert m.work_saved == pytest.approx(0.50005, rel=1e-9)
    # the rescue segment's own prediction was accurate
    assert m.plan_errors[0] == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# Watchdog: stall flag -> eviction of the straggling provider
# ---------------------------------------------------------------------------

class _OrderedPickSim(_CheckedSim):
    """Deterministic provider choice honoring survivors and avoid, so the
    eviction -> fresh-helper path has a closed form."""

    def _pick_providers(self, failed, healthy, survivors=(), d=None,
                        avoid=()):
        d = d or self.params.d
        pool = [h for h in healthy if h != failed and h not in avoid]
        keep = [s for s in survivors if s in pool]
        return (keep + [h for h in pool if h not in keep])[:d]


def test_watchdog_evicts_stalled_provider_and_retries():
    """Node 1 stalls outright (factor 0) before the repair 1 -> 2 -> 0
    starts; the believed view never learns (estimates off), so the rescue
    replan at the first flag (t=0.05) is accepted but equally stalled.
    The second flag (t=0.1, after 1x backoff) escalates: provider 1 — the
    source of the infinite-residual bottleneck link — is evicted, and
    re-admission draws the fresh helper 3, finishing 0.1 s later.  The
    believed-ETA prediction of the final segment is exact."""
    _, model = _flat_caps(4)
    sc = Scenario(num_nodes=4, duration=1.0, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  degradations=((0.0, 1, 0.0, 1000.0),),
                  watchdog_period=0.05)
    sim = _OrderedPickSim(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0)
    m = sim.run()
    assert m.completed == 1
    assert m.watchdog_flags == 2
    assert m.watchdog_replans == 1          # accepted but useless
    assert m.evictions == 1 and m.watchdog_giveups == 0
    assert m.aborted == 0                   # evictions are not aborts
    assert m.vulnerability_windows[0] == pytest.approx(0.2, abs=1e-12)
    assert m.plan_errors[0] == pytest.approx(0.0, abs=1e-9)
    assert sim.shares.users == {}           # everything released


def test_watchdog_gives_up_when_no_alternative_helper():
    """n=3 leaves exactly two possible providers, one of them stalled
    forever: every eviction redraws the same stalled helper (the avoid
    list is best-effort by design — starving the repair would be worse).
    The mitigation ladder runs 1 replan + watchdog_retries evictions with
    exponential backoff (flags at 0.05, 0.1, 0.2, 0.4), then the flag at
    0.8 exhausts the budget: give-up, and no further flags ever."""
    _, model = _flat_caps(3)
    sc = Scenario(num_nodes=3, duration=5.0, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  degradations=((0.0, 1, 0.0, 1000.0),),
                  watchdog_period=0.05, watchdog_retries=3,
                  watchdog_backoff=2.0)
    m = _CheckedSim(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 0                 # the stall never clears
    assert m.watchdog_flags == 5            # 1 replan + 3 evicts + give-up
    assert m.watchdog_replans == 1
    assert m.evictions == 3
    assert m.watchdog_giveups == 1
    assert m.aborted == 0


# ---------------------------------------------------------------------------
# Graceful degradation: functional repair with d' in [k, d) helpers
# ---------------------------------------------------------------------------

def test_degraded_d_admission_when_helpers_scarce():
    """Three simultaneous failures leave 5 healthy nodes in an 8-slot
    cluster — below d=6 but above k=3.  Without degraded_d every repair
    queues until the population recovers (which never happens with the
    failure process off); with it, all three are admitted with d'=5
    helpers and complete."""
    caps = np.random.default_rng(2).uniform(10.0, 120.0, size=(8, 8))
    np.fill_diagonal(caps, 0.0)
    model = (lambda rng, m: caps.copy())
    base = dict(num_nodes=8, duration=200.0, failure_rate=0.0,
                failures=((0.0, 0), (0.0, 1), (0.0, 2)),
                capacity_model=model)
    stuck = simulate(Scenario(**base), FlexiblePolicy(), PARAMS, seed=0)
    assert stuck["completed"] == 0 and stuck["degraded_admissions"] == 0
    _CheckedSim.checks = 0
    m = _CheckedSim(Scenario(degraded_d=True, **base), FlexiblePolicy(),
                    PARAMS, seed=0).run()
    assert m.completed == 3
    assert m.degraded_admissions == 3
    assert _CheckedSim.checks > 0


# ---------------------------------------------------------------------------
# Drain-queue rollback: a provider-picker error must not wedge the cluster
# ---------------------------------------------------------------------------

def _picky_picker(failed, healthy, rng):
    if failed == 1:
        raise ValueError("picker deliberately failing for slot 1")
    return [4, 5]


def _dup_picker(failed, healthy, rng):
    return [4, 4]


@pytest.mark.parametrize("picker,match", [
    (_picky_picker, "deliberately failing"),
    (_dup_picker, "distinct providers"),
])
def test_drain_queue_rolls_back_on_picker_error(picker, match):
    """A picker error mid-batch must roll back every slot the batch
    already flipped to REPAIRING and restore the queue in order — not
    leave slots wedged in REPAIRING with no active repair that could ever
    complete them.  ``_picky_picker`` raises on the second slot of the
    batch (exercising multi-slot rollback); ``_dup_picker`` trips the
    distinct-providers check on the first."""
    _, model = _flat_caps(6)
    sc = Scenario(num_nodes=6, duration=10.0, failure_rate=0.0,
                  capacity_model=model, provider_picker=picker,
                  max_concurrent=8)
    sim = FleetSimulator(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0)
    for node in (0, 1):
        sim.cluster.fail(node)
        sim.queue.append(QueuedRepair(0.0, node))
    with pytest.raises(ValueError, match=match):
        sim._drain_queue()
    # both slots are back to FAILED (not REPAIRING), requeued, no links held
    assert sim.cluster.state[0] == FAILED
    assert sim.cluster.state[1] == FAILED
    assert REPAIRING not in sim.cluster.state
    assert [q.node for q in sim.queue] == [0, 1]
    assert sim.active == [] and sim.shares.users == {}


def test_pick_providers_avoid_is_best_effort():
    _, model = _flat_caps(8)
    sc = Scenario(num_nodes=8, duration=1.0, capacity_model=model)
    sim = FleetSimulator(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=3)
    healthy = list(range(1, 8))
    # enough alternatives: the avoid list is honored
    got = sim._pick_providers(0, healthy, d=2, avoid=(1, 2, 3, 4, 5))
    assert sorted(got) == [6, 7]
    # thin pool: avoiding would starve the repair, so avoid is dropped
    got = sim._pick_providers(0, healthy, d=2, avoid=(1, 2, 3, 4, 5, 6))
    assert len(set(got)) == 2 and all(h in healthy for h in got)


# ---------------------------------------------------------------------------
# Scenario validation (hardening satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(max_concurrent=0), "max_concurrent"),
    (dict(rack_burst_prob=1.5), "rack_burst_prob"),
    (dict(rack_size=4, rack_burst_extra=-1), "rack_burst_extra"),
    (dict(read_fanin=-1), "read_fanin"),
    (dict(estimate_noise=1.0), "estimate_noise"),
    (dict(estimate_refresh_period=-1.0), "estimate_refresh_period"),
    (dict(degrade_rate=-1e-3), "degrade_rate"),
    (dict(degrade_rate=1e-3), "degrade_mean_duration"),
    (dict(degrade_lo=0.5, degrade_hi=0.2), "degrade_lo"),
    (dict(degrade_hi=1.0), "below 1"),
    (dict(degradations=((-1.0, 0, 0.5, 1.0),)), "degradation injection"),
    (dict(degradations=((1.0, 0, 1.5, 1.0),)), "degradation injection"),
    (dict(watchdog_period=-1.0), "watchdog_period"),
    (dict(watchdog_lag=0.5), "watchdog_lag"),
    (dict(watchdog_retries=-1), "watchdog_retries"),
    (dict(watchdog_backoff=0.5), "watchdog_backoff"),
])
def test_scenario_validation_messages(kwargs, match):
    with pytest.raises(ValueError, match=match):
        Scenario(num_nodes=8, duration=100.0, **kwargs)


def test_scenario_robustness_defaults_are_inert():
    sc = Scenario(num_nodes=8, duration=100.0)
    assert sc.estimate_noise == 0.0 and sc.estimate_refresh_period == 0.0
    assert sc.degrade_rate == 0.0 and sc.degradations == ()
    assert sc.watchdog_period == 0.0 and not sc.degraded_d


# ---------------------------------------------------------------------------
# Acceptance: mitigation strictly pays for itself on seeded stragglers
# ---------------------------------------------------------------------------

def test_mitigation_strictly_improves_seeded_stragglers():
    """On the stragglers scenario (silent brownouts the abort path cannot
    see), the watchdog + retry + degraded-d stack must STRICTLY improve
    both mean backlog and the p99 vulnerability window at the same seed,
    and must actually act (flags, evictions) rather than win by luck."""
    sc = stragglers(16, duration=2000.0)
    base = simulate(sc, FlexiblePolicy(), PARAMS, seed=7)
    mit = simulate(mitigated(sc), FlexiblePolicy(), PARAMS, seed=7)
    assert base["watchdog_flags"] == 0 and base["evictions"] == 0
    assert mit["watchdog_flags"] > 0
    assert mit["watchdog_replans"] + mit["evictions"] > 0
    assert mit["mean_backlog"] < base["mean_backlog"]
    assert mit["vulnerability_p99"] < base["vulnerability_p99"]
    # mitigation also tightens the plan-error tail: rescued/evicted
    # segments get re-predicted against fresher knowledge
    assert mit["plan_err_p99"] < base["plan_err_p99"]


def test_conservation_under_mitigation_stress():
    """The PR-3 invariant holds through the full mitigation machinery on
    a seeded brownout-heavy run: banked + outstanding == plan total at
    every epoch, across watchdog replans and evictions."""
    _CheckedSim.checks = 0
    sc = mitigated(stragglers(12, duration=1500.0))
    acted = 0
    for seed in (0, 1):
        m = _CheckedSim(sc, FlexiblePolicy(), PARAMS, seed=seed).run()
        acted += m.watchdog_replans + m.evictions
    assert _CheckedSim.checks > 200
    assert acted > 0
