"""Exact min-traffic witness oracle vs the scipy/HiGHS LP (repro.core.witness).

Equivalence contract (see the witness module docstring):

* star case — at the planners' query time (the bisection optimum of problem
  (1)) the level-cut point coincides with HiGHS's vertex choice *per edge*
  to 1e-9; at strictly-interior times the optimal face can be degenerate
  (e.g. k=1, where only the total binds) and only the objective is pinned.
* tree case — the level cut of the water-fill witness attains the LP
  optimum of sum(beta) and the same repair time; on degenerate faces HiGHS
  may return a different optimal vertex, so per-edge equality is asserted
  against the batched oracle (bitwise determinism), not against the solver.

The sweep covers MSR / interior / MBR operating points and degenerate
capacities: exact ties, zero-capacity links, and the single-helper code
(k = d = 1).  A seeded deterministic sweep always runs; the hypothesis
property test widens it when hypothesis is installed (CI always has it).
"""
import math
import random

import numpy as np
import pytest

from repro.core import CodeParams, mbr_point
from repro.core import lp
from repro.core import witness as wit
from repro.core.lp import HAVE_SCIPY
from repro.core.regions import FeasibleRegion, heuristic_region, msr_region

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal local env; CI installs hypothesis
    HAVE_HYPOTHESIS = False

needs_scipy = pytest.mark.skipif(not HAVE_SCIPY, reason="scipy unavailable")


# ---------------------------------------------------------------------------
# Instance family (mirrors the planners' usage)
# ---------------------------------------------------------------------------

def _instance(seed: int):
    """Random (params, region, caps) across MSR/interior/MBR with degenerate
    capacity patterns: exact ties, zero links, single helper."""
    rng = random.Random(seed)
    k = rng.choice([1, 2, 3, 4, 5])
    d = rng.randint(k, k + 9)
    if rng.random() < 0.05:
        k = d = 1                       # single-helper code
    M = float(rng.choice([120, 600, 8000]))
    a_msr = M / k
    try:
        a_mbr, _ = mbr_point(M, k, d)
    except ZeroDivisionError:
        a_mbr = a_msr
    alpha = rng.choice([a_msr, a_mbr, 0.5 * (a_msr + a_mbr)])
    params = CodeParams(n=d + 2, k=k, d=d, M=M, alpha=alpha)
    region = msr_region(params) if params.is_msr else heuristic_region(params)
    caps = [rng.uniform(0.3, 120.0) for _ in range(d)]
    r = rng.random()
    if r < 0.15:
        caps = [rng.choice([20.0, 50.0]) for _ in range(d)]  # exact ties
    elif r < 0.25:
        caps[rng.randrange(d)] = 0.0                         # dead link
    return params, region, caps


def _random_tree(rng: random.Random, d: int):
    parent = {}
    order = list(range(1, d + 1))
    rng.shuffle(order)
    placed = [0]
    for u in order:
        parent[u] = rng.choice(placed)
        placed.append(u)
    return parent


def _check_star(seed: int) -> None:
    params, region, caps = _instance(seed)
    alpha = params.alpha
    t = lp.minmax_time_star(caps, region, alpha)
    if not math.isfinite(t):
        return
    exact = np.array(lp.min_traffic_at_time(t, caps, region, alpha))
    sol = np.array(lp.min_traffic_at_time(t, caps, region, alpha,
                                          witness="lp"))
    # per-edge equivalence at the planner's query time: the optimal face
    # collapses at the bisection optimum, and the level-cut point is
    # exactly HiGHS's vertex there
    np.testing.assert_allclose(exact, sol, rtol=1e-9, atol=1e-9)
    # witness validity and structure
    ub = np.minimum(t * np.asarray(caps), alpha)
    assert region.contains(exact.tolist(), tol=1e-7)
    assert (exact <= ub + 1e-12).all() and (exact >= -1e-12).all()
    np.testing.assert_allclose(exact, np.minimum(ub, exact.max()),
                               rtol=0, atol=1e-12)
    # at strictly-interior times the face may be degenerate (k=1: only the
    # total binds) — there the contract is objective equality
    for mult in (1.3, 2.5):
        e2 = np.array(lp.min_traffic_at_time(mult * t, caps, region, alpha))
        s2 = np.array(lp.min_traffic_at_time(mult * t, caps, region, alpha,
                                             witness="lp"))
        assert e2.sum() == pytest.approx(s2.sum(), rel=1e-9, abs=1e-9)
        assert region.contains(e2.tolist(), tol=1e-7)


def _check_tree(seed: int) -> None:
    params, region, caps_direct = _instance(seed)
    d, alpha = params.d, params.alpha
    rng = random.Random(seed + 77)
    parent = _random_tree(rng, d)
    cap_of_edge = {(u, p): (caps_direct[u - 1] if rng.random() < 0.5
                            else rng.uniform(0.3, 120.0))
                   for u, p in parent.items()}
    t, _ = lp.tree_optimal_time(parent, cap_of_edge, region, alpha, iters=50)
    if not math.isfinite(t):
        return
    exact = lp.tree_feasible_at_time(t, parent, cap_of_edge, region, alpha,
                                     minimize_traffic=True)
    sol = lp.tree_feasible_at_time(t, parent, cap_of_edge, region, alpha,
                                   minimize_traffic=True, witness="lp")
    wf = lp.tree_feasible_at_time(t, parent, cap_of_edge, region, alpha)
    assert exact is not None and wf is not None
    exact = np.array(exact)
    # LP-optimality of the exact witness: equal objective (generated
    # traffic), equal repair time, and feasibility — HiGHS may sit on a
    # different vertex of the same optimal face, so per-edge equality
    # against the solver is only guaranteed where the face is a point
    if sol is not None:
        assert exact.sum() == pytest.approx(np.sum(sol), rel=1e-9, abs=1e-7)
        t_ex = _tree_time(parent, exact, cap_of_edge, alpha)
        t_lp = _tree_time(parent, np.array(sol), cap_of_edge, alpha)
        assert t_ex == pytest.approx(t_lp, rel=1e-9, abs=1e-9)
    assert region.contains(exact.tolist(), tol=1e-7)
    # the level cut respects every laminar subtree cap (it is <= wf)
    assert (exact <= np.array(wf) + 1e-12).all()
    np.testing.assert_allclose(exact, np.minimum(wf, exact.max()),
                               rtol=0, atol=1e-12)


def _tree_time(parent, betas, cap_of_edge, alpha) -> float:
    from repro.core import tree_flows

    flows = tree_flows(parent, betas.tolist(), alpha)
    return max((f / cap_of_edge[e] if cap_of_edge[e] > 0 else math.inf)
               for e, f in flows.items())


# ---------------------------------------------------------------------------
# Seeded deterministic sweep (runs everywhere, no hypothesis needed)
# ---------------------------------------------------------------------------

@needs_scipy
@pytest.mark.parametrize("seed", range(0, 40))
def test_star_witness_matches_lp_seeded(seed):
    _check_star(seed)


@needs_scipy
@pytest.mark.parametrize("seed", range(0, 40))
def test_tree_witness_matches_lp_seeded(seed):
    _check_tree(seed)


if HAVE_HYPOTHESIS:
    @needs_scipy
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000))
    def test_star_witness_matches_lp_property(seed):
        """Property form of the star equivalence (wider random family)."""
        _check_star(seed)

    @needs_scipy
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000))
    def test_tree_witness_matches_lp_property(seed):
        """Property form of the tree equivalence (wider random family)."""
        _check_tree(seed)


# ---------------------------------------------------------------------------
# Batched entry points: bitwise determinism and scalar agreement
# ---------------------------------------------------------------------------

def test_min_traffic_batch_matches_scalar_bitwise():
    """The batched star witness equals the scalar wrapper lane by lane
    (same arithmetic), and is invariant to batch composition."""
    rng = random.Random(3)
    params, region, _ = _instance(123)
    d, alpha = params.d, params.alpha
    B = 17
    direct = np.array([[rng.uniform(0.3, 120.0) for _ in range(d)]
                       for _ in range(B)])
    t = np.empty(B)
    for b in range(B):
        t[b] = lp.minmax_time_star(direct[b].tolist(), region, alpha)
    got = wit.min_traffic_batch(t, direct, region, alpha)
    for b in range(B):
        want = wit.min_traffic(float(t[b]), direct[b].tolist(), region, alpha)
        np.testing.assert_array_equal(got[b], want)
    perm = rng.sample(range(B), B)
    np.testing.assert_array_equal(
        wit.min_traffic_batch(t[perm], direct[perm], region, alpha),
        got[perm])


def test_min_traffic_batch_poisons_dead_lanes():
    """Non-finite times (infeasible star problems) produce zero betas, the
    plan_fr_batch convention for lanes it later poisons to inf."""
    region = FeasibleRegion(k=2, d=3, x=(10.0, 20.0))
    t = np.array([math.inf, 1.0])
    direct = np.array([[0.0, 0.0, 0.0], [30.0, 30.0, 30.0]])
    out = wit.min_traffic_batch(t, direct, region, alpha=15.0)
    assert (out[0] == 0.0).all()
    assert region.contains_batch(out[1:2])[0]


def test_level_cut_rejects_infeasible_max_point():
    """An infeasible ub on a live lane raises (the old scipy-absent greedy's
    contract) instead of returning a silently invalid witness; dead lanes
    are exempt."""
    region = FeasibleRegion(k=2, d=3, x=(4.0, 200.0))
    ub_bad = np.array([[1.0, 1.0, 100.0]])    # sigma_1(ub) = 2 < 4 and
    with pytest.raises(ValueError, match="coordinate-wise max point"):
        wit.level_cut_batch(ub_bad, region)   # sigma_2(ub) = 102 < 200
    lanes = np.array([False])
    out = wit.level_cut_batch(ub_bad, region, lanes=lanes)  # masked: no raise
    assert out.shape == (1, 3)


def test_planners_reject_unknown_witness_eagerly():
    """plan_fr (even on the MSR closed-form path, which never consults the
    engine) and plan_ftr validate the witness string before doing work."""
    from repro.core import OverlayNetwork, plan_fr, plan_ftr

    params = CodeParams.msr(n=12, k=3, d=4, M=120.0)
    cap = [[0.0 if u == v else 50.0 for v in range(5)] for u in range(5)]
    net = OverlayNetwork(cap)
    with pytest.raises(ValueError, match="unknown witness"):
        plan_fr(net, params, witness="LP")
    with pytest.raises(ValueError, match="unknown witness"):
        plan_ftr(net, params, witness="bogus")


def test_tree_traffic_batch_matches_scalar_path():
    """tree_traffic_batch reproduces the scalar exact tree witness on
    random trees (same water-fill + level cut, batched)."""
    rng = random.Random(9)
    params, region, _ = _instance(456)
    d, alpha = params.d, params.alpha
    B = 11
    parents_l, caps_l, ts, want = [], [], [], []
    while len(parents_l) < B:
        parent = _random_tree(rng, d)
        cap_of_edge = {(u, p): rng.uniform(1.0, 120.0)
                       for u, p in parent.items()}
        t, _ = lp.tree_optimal_time(parent, cap_of_edge, region, alpha,
                                    iters=50)
        if not math.isfinite(t):
            continue
        w = lp.tree_feasible_at_time(t, parent, cap_of_edge, region, alpha,
                                     minimize_traffic=True)
        assert w is not None
        cap = np.zeros((d + 1, d + 1))
        par = np.zeros(d + 1, dtype=np.int64)
        for (u, p), c in cap_of_edge.items():
            cap[u, p] = c
            par[u] = p
        parents_l.append(par)
        caps_l.append(cap)
        ts.append(t)
        want.append(w)
    got = wit.tree_traffic_batch(np.array(ts), np.array(parents_l),
                                 np.array(caps_l), region, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Planner integration: witness="lp" escape hatch
# ---------------------------------------------------------------------------

@needs_scipy
def test_planners_lp_escape_hatch_agrees_on_time_and_generated_traffic():
    """plan_fr / plan_ftr with witness="lp" produce the same repair time and
    generated traffic sum(beta) as the default exact oracle; for the star
    planner the betas agree per edge."""
    from repro.core import OverlayNetwork, plan_fr, plan_ftr

    rng = random.Random(21)
    for point in range(3):
        M, k, d = 600.0, 3, 6
        a_msr = M / k
        a_mbr, _ = mbr_point(M, k, d)
        alpha = [a_msr, 0.5 * (a_msr + a_mbr), a_mbr][point]
        params = CodeParams(n=12, k=k, d=d, M=M, alpha=alpha)
        for _ in range(4):
            cap = [[0.0] * (d + 1) for _ in range(d + 1)]
            for u in range(d + 1):
                for v in range(d + 1):
                    if u != v:
                        cap[u][v] = rng.uniform(10.0, 120.0)
            net = OverlayNetwork(cap)
            fr_e, fr_l = plan_fr(net, params), plan_fr(net, params,
                                                       witness="lp")
            assert fr_e.time == pytest.approx(fr_l.time, rel=1e-9)
            np.testing.assert_allclose(fr_e.betas, fr_l.betas,
                                       rtol=1e-7, atol=1e-7)
            ftr_e, ftr_l = plan_ftr(net, params), plan_ftr(net, params,
                                                           witness="lp")
            assert ftr_e.time == pytest.approx(ftr_l.time, rel=1e-9)
            assert ftr_e.parent == ftr_l.parent
            assert sum(ftr_e.betas) == pytest.approx(sum(ftr_l.betas),
                                                     rel=1e-9, abs=1e-7)


def test_compare_schemes_witness_engines_agree():
    """compare_schemes(witness='lp') reproduces the default exact oracle's
    mean times (the plans are the same trees/stars at the same times)."""
    if not HAVE_SCIPY:
        pytest.skip("scipy unavailable")
    from repro.storage import compare_schemes, uniform

    params = CodeParams.msr(n=12, k=3, d=5, M=300.0)
    a = compare_schemes(params, uniform(), ("fr", "ftr"), trials=6, seed=4)
    b = compare_schemes(params, uniform(), ("fr", "ftr"), trials=6, seed=4,
                        witness="lp")
    for s in ("fr", "ftr"):
        assert a[s].mean_time == pytest.approx(b[s].mean_time, rel=1e-9)
        assert a[s].mean_norm_time == pytest.approx(b[s].mean_norm_time,
                                                    rel=1e-9)
