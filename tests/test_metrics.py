"""FleetMetrics unit coverage (ISSUE 7 satellite).

The fleet tests exercise the metrics through whole simulations; these pin
the accumulator itself:

* ``_pct`` closed forms — empty list, single sample, ties, and numpy's
  linear interpolation between order statistics;
* the counter round-trip contract — every monotone counter listed in
  ``COUNTER_SUMMARY_KEYS`` lands in ``summary()`` under its declared key
  after its ``on_*`` hook fires (a counter added without a summary key,
  or renamed on one side only, fails here);
* ``observe`` closed forms — time-weighted mean backlog, the monotone
  clock, and the MTTDL intensity accruing past the loss boundary.
"""
import math

import pytest

from repro.fleet import FleetMetrics
from repro.fleet.metrics import COUNTER_SUMMARY_KEYS


def _metrics(**kw) -> FleetMetrics:
    kw.setdefault("n", 12)
    kw.setdefault("k", 3)
    kw.setdefault("failure_rate", 1e-3)
    return FleetMetrics(**kw)


# ---------------------------------------------------------------------------
# _pct closed forms
# ---------------------------------------------------------------------------

def test_pct_empty_is_zero():
    for q in (0, 50, 99, 100):
        assert FleetMetrics._pct([], q) == 0.0


def test_pct_single_sample_is_that_sample():
    for q in (0, 50, 99, 100):
        assert FleetMetrics._pct([5.0], q) == 5.0


def test_pct_ties_collapse():
    assert FleetMetrics._pct([3.0, 3.0, 3.0, 3.0], 99) == 3.0
    assert FleetMetrics._pct([3.0, 3.0, 3.0, 3.0], 50) == 3.0


def test_pct_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    # numpy's default "linear" method: position (n-1) * q/100
    assert FleetMetrics._pct(xs, 50) == pytest.approx(2.5)
    assert FleetMetrics._pct(xs, 99) == pytest.approx(3.97)
    assert FleetMetrics._pct(xs, 0) == 1.0
    assert FleetMetrics._pct(xs, 100) == 4.0


def test_pct_order_invariant():
    assert (FleetMetrics._pct([4.0, 1.0, 3.0, 2.0], 50)
            == FleetMetrics._pct([1.0, 2.0, 3.0, 4.0], 50))


# ---------------------------------------------------------------------------
# counter round-trip: every COUNTER_SUMMARY_KEYS attr reaches summary()
# ---------------------------------------------------------------------------

def _fire_all_counters(m: FleetMetrics) -> None:
    """Call every on_* hook at least once with distinct-looking args."""
    m.observe(0.0, 2, 0)
    m.observe(5.0, 1, 0)
    m.on_complete(fail_time=0.0, start_time=1.0, end_time=5.0,
                  plan_t0=1.0, predicted=2.0)
    m.on_abort(carryover=True)
    m.on_abort(carryover=False)
    m.on_carryover(saved=30.0, planned=100.0)
    m.on_migration(saved=10.0, planned=50.0)
    m.on_data_loss()
    m.on_watchdog_flag()
    m.on_watchdog_replan(saved=5.0, planned=20.0)
    m.on_eviction()
    m.on_watchdog_giveup()
    m.on_degraded_admission()
    m.on_degrade()
    # data-plane hooks (ISSUE 10): these also latch the dataplane flag, so
    # the conditional summary keys surface for the round-trip asserts
    m.on_read_complete(2.0, 1024.0)
    m.on_read_drop()
    m.on_read_teardown(128.0)
    m.on_repair_bytes(2048.0)
    m.on_decode_check(True)
    m.on_decode_check(False)


def test_every_counter_round_trips_into_summary():
    m = _metrics()
    _fire_all_counters(m)
    summary = m.summary()
    for attr, key in COUNTER_SUMMARY_KEYS.items():
        assert key in summary, f"{attr}: summary key {key!r} missing"
        assert summary[key] == getattr(m, attr), \
            f"{attr}: summary[{key!r}]={summary[key]!r} != " \
            f"attribute {getattr(m, attr)!r}"


def test_counters_moved_off_zero():
    """The round-trip test is vacuous if a hook never fires its counter."""
    m = _metrics()
    _fire_all_counters(m)
    for attr in COUNTER_SUMMARY_KEYS:
        assert getattr(m, attr) > 0, f"{attr} never incremented"


def test_abort_split_and_migration_bookkeeping():
    m = _metrics()
    m.on_abort(carryover=True)
    m.on_abort(carryover=False)
    m.on_abort(carryover=False)
    assert (m.aborted, m.carryover_aborts, m.cold_aborts) == (3, 1, 2)
    m.on_migration(saved=25.0, planned=100.0)
    assert m.migrations == 1 and m.work_saved == 25.0
    assert m.credit_fractions == [0.25]
    # zero-planned credit must not divide by zero
    m.on_carryover(saved=0.0, planned=0.0)
    assert m.credit_fractions[-1] == 0.0


# ---------------------------------------------------------------------------
# observe closed forms
# ---------------------------------------------------------------------------

def test_mean_backlog_time_weighted():
    m = _metrics()
    m.observe(0.0, 2, 0)
    m.observe(10.0, 0, 0)      # 2 repairs pending for 10s
    m.observe(20.0, 0, 0)      # then idle for 10s
    s = m.summary()
    assert s["mean_backlog"] == pytest.approx(1.0)
    assert s["max_backlog"] == 2


def test_observe_rejects_backwards_time():
    m = _metrics()
    m.observe(5.0, 0, 0)
    with pytest.raises(ValueError):
        m.observe(4.0, 0, 0)


def test_mttdl_intensity_accrues_past_boundary():
    # n=4, k=2: the at-risk boundary is n-k = 2 slots down
    m = _metrics(n=4, k=2, failure_rate=0.1)
    m.observe(0.0, 0, 2)
    m.observe(10.0, 0, 3)      # 10s at the boundary: rate * healthy=2
    m.observe(20.0, 0, 0)      # 10s past it: rate * healthy=1
    assert m.expected_losses == pytest.approx(0.1 * 2 * 10 + 0.1 * 1 * 10)
    assert m.summary()["mttdl_estimate"] == pytest.approx(
        20.0 / m.expected_losses)


def test_mttdl_infinite_when_never_at_risk():
    m = _metrics()
    m.observe(0.0, 0, 0)
    m.observe(10.0, 0, 0)
    assert math.isinf(m.summary()["mttdl_estimate"])


def test_plan_error_relative():
    m = _metrics()
    m.on_complete(fail_time=0.0, start_time=1.0, end_time=5.0,
                  plan_t0=1.0, predicted=2.0)
    # realized 4s against a 2s prediction: +100% late
    assert m.plan_errors == [pytest.approx(1.0)]
    # non-finite or missing predictions record nothing
    m.on_complete(0.0, 1.0, 5.0, plan_t0=1.0, predicted=math.inf)
    m.on_complete(0.0, 1.0, 5.0)
    assert len(m.plan_errors) == 1
