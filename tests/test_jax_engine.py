"""The jit-compiled jax planning tier vs the NumPy engines, and the
ragged-d (mixed fan-out) batch API.

Cross-engine contract (documented in repro.core.jax_engine and enforced in
CI by benchmarks/check_engine_parity.py): tree topology (``parents``) is
bitwise equal to the NumPy engines — any divergence is algorithmic drift —
and star times are bitwise too; all other floats agree within 1e-9
relative (XLA may re-associate reductions, e.g. the traffic sum, which
permits ~1-ulp differences; measured drift is ~1e-14).

The batches here are deliberately small (d in {4, 6}): the jax engine
compiles one executable per (batch, d) shape and compilation, not
planning, dominates test wall time.
"""
import dataclasses
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (CodeParams, OverlayNetwork, caps_tensor, mbr_point,
                        plan, plan_many, plans_from_batch)
from repro.core.api import get_scheme, scheme_names

JAX_SCHEMES = ("star", "fr", "tr", "ftr")
REL_TOL = 1e-9


def _caps(seed: int, B: int, d: int, lo=10.0, hi=120.0) -> np.ndarray:
    rng = np.random.default_rng([seed, 0x1A2])
    caps = rng.uniform(lo, hi, size=(B, d + 1, d + 1))
    idx = np.arange(d + 1)
    caps[:, idx, idx] = 0.0
    return caps


def _params(d: int, k: int, interior: bool) -> CodeParams:
    M = 600.0
    if not interior:
        return CodeParams.msr(n=d + 2, k=k, d=d, M=M)
    a_mbr, _ = mbr_point(M, k, d)
    return CodeParams(n=d + 2, k=k, d=d, M=M, alpha=0.5 * (M / k + a_mbr))


def _assert_close(a, b, msg):
    np.testing.assert_allclose(np.asarray(a, dtype=float),
                               np.asarray(b, dtype=float),
                               rtol=REL_TOL, atol=REL_TOL, err_msg=msg)


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------

def test_registry_declares_jax_tier():
    assert scheme_names(jax=True) == JAX_SCHEMES
    for s in JAX_SCHEMES:
        assert get_scheme(s).jax is not None
    for s in ("shah", "rctree"):
        assert get_scheme(s).jax is None


@pytest.mark.parametrize("scheme", ["shah", "rctree"])
def test_jax_fallback_warns_once_per_scheme(scheme):
    from repro.core import api

    params = _params(6, 3, interior=False)
    caps = _caps(0, 4, 6)
    api._warned_jax_fallback.discard(scheme)
    with pytest.warns(RuntimeWarning, match="no JAX planner available"):
        res = plan_many(caps, params, scheme, engine="jax")
    # shah degrades to its batched planner, rctree all the way to scalar
    assert res.engine == ("batched" if scheme == "shah" else "scalar")
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second call must be silent
        plan_many(caps, params, scheme, engine="jax")


# ---------------------------------------------------------------------------
# Cross-engine parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interior", [False, True],
                         ids=["msr", "interior"])
def test_jax_matches_batched_and_scalar(interior):
    params = _params(6, 3, interior)
    caps = _caps(7 + interior, 9, 6)
    nets = [OverlayNetwork(c.tolist()) for c in caps]
    for s in JAX_SCHEMES:
        rj = plan_many(caps, params, s, engine="jax")
        rb = plan_many(caps, params, s, engine="batched")
        assert rj.engine == "jax"
        assert (rj.parents == rb.parents).all(), f"{s}: parents drifted"
        if s == "star":
            assert (rj.times == rb.times).all(), "star times must be bitwise"
        _assert_close(rj.times, rb.times, f"{s}: times")
        _assert_close(rj.traffic, rb.traffic, f"{s}: traffic")
        _assert_close(rj.betas, rb.betas, f"{s}: betas")
        if rb.lower_bounds is not None:
            _assert_close(rj.lower_bounds, rb.lower_bounds, f"{s}: lb")
        # direct tie to the scalar oracle on a row subset
        for b in range(3):
            ps = plan(nets[b], params, s, engine="scalar")
            assert abs(rj.times[b] - ps.time) <= REL_TOL * max(1, ps.time), s
            got_par = {u: int(rj.parents[b, u]) for u in range(1, params.d + 1)}
            assert got_par == ps.parent, f"{s}: row {b} tree differs"


def test_jax_plan_single_network_roundtrip():
    """plan(engine='jax') rides the B=1 batch path and materializes a
    RepairPlan that validates structurally against the overlay."""
    params = _params(4, 2, interior=True)
    net = OverlayNetwork(_caps(3, 1, 4)[0].tolist())
    for s in JAX_SCHEMES:
        pj = plan(net, params, s, engine="jax")
        po = plan(net, params, s, engine="scalar")
        assert pj.time == pytest.approx(po.time, rel=REL_TOL)
        assert pj.parent == po.parent
        pj.validate(net)


def test_jax_rejects_lp_witness():
    params = _params(4, 2, interior=True)
    caps = _caps(4, 2, 4)
    with pytest.raises(ValueError, match="witness"):
        plan_many(caps, params, "fr", engine="jax", witness="lp")


# ---------------------------------------------------------------------------
# Ragged-d (mixed fan-out) batches
# ---------------------------------------------------------------------------

def _ragged_nets(seed: int):
    """Mixed fan-outs out of input order on purpose: 6, 4, 6, 5, 4."""
    ds = [6, 4, 6, 5, 4]
    return [OverlayNetwork(_caps(seed + i, 1, d)[0].tolist())
            for i, d in enumerate(ds)], ds


@pytest.mark.parametrize("engine", ["batched", "jax", "scalar"])
def test_ragged_matches_per_overlay_scalar(engine):
    """Each row of a mixed-d batch equals planning that overlay alone with
    params re-targeted to its d — bitwise for batched/scalar (same NumPy
    code path), 1e-9 for jax — and rows come back in input order."""
    params = _params(6, 3, interior=False)
    nets, ds = _ragged_nets(11)
    for s in ("fr", "ftr"):
        res = plan_many(nets, params, s, engine=engine)
        assert res.engine == engine
        assert res.betas.shape == (len(nets), max(ds))
        assert res.parents.shape == (len(nets), max(ds) + 1)
        for i, (net, d) in enumerate(zip(nets, ds)):
            pd = dataclasses.replace(params, d=d)
            ps = plan(net, pd, s, engine="scalar")
            if engine == "jax":
                assert res.times[i] == pytest.approx(ps.time, rel=REL_TOL)
                np.testing.assert_allclose(res.betas[i, :d], ps.betas,
                                           rtol=REL_TOL, atol=REL_TOL)
            else:
                assert res.times[i] == ps.time, (s, i)
                assert list(res.betas[i, :d]) == ps.betas, (s, i)
            assert {u: int(res.parents[i, u])
                    for u in range(1, d + 1)} == ps.parent, (s, i)
            # padding beyond the overlay's own d stays zero
            assert (res.betas[i, d:] == 0).all()
            assert (res.parents[i, d + 1:] == 0).all()
            # the materialized plan carries its true fan-out
            assert res.plans[i].params.d == d


def test_ragged_plans_roundtrip_verbatim():
    params = _params(6, 3, interior=False)
    nets, ds = _ragged_nets(13)
    res = plan_many(nets, params, "ftr", engine="batched")
    plans = plans_from_batch(res, params)
    for pl, net, d in zip(plans, nets, ds):
        assert pl.params.d == d
        pl.validate(net)


def test_single_d_batch_degenerates_to_existing_path():
    """A sequence of same-d overlays must NOT take the ragged path: one
    engine call, results bitwise identical to the tensor entry point."""
    params = _params(6, 3, interior=False)
    caps = _caps(17, 6, 6)
    nets = [OverlayNetwork(c.tolist()) for c in caps]
    direct = plan_many(caps, params, "ftr", engine="batched")
    via_seq = plan_many(nets, params, "ftr", engine="batched")
    assert via_seq.engine == "batched"
    assert (via_seq.times == direct.times).all()
    assert (via_seq.parents == direct.parents).all()
    assert (via_seq.betas == direct.betas).all()
    assert via_seq.plans is None            # batched path attaches no plans


def test_ragged_infeasible_overlay_too_small():
    """An overlay with d < k cannot serve the code: params re-validation
    fails loudly instead of planning nonsense."""
    params = _params(6, 3, interior=False)
    nets = [OverlayNetwork(_caps(19, 1, 6)[0].tolist()),
            OverlayNetwork(_caps(20, 1, 2)[0].tolist())]   # d=2 < k=3
    with pytest.raises(ValueError, match="k <= d"):
        plan_many(nets, params, "fr", engine="batched")


# ---------------------------------------------------------------------------
# Mixed-engine FlexiblePolicy
# ---------------------------------------------------------------------------

def test_flexible_policy_mixed_engines():
    """engine='jax' routes jax-capable schemes through the jit tier while
    rctree (scalar-only) loops the oracle — no warning, the downgrade is
    policy-resolved — and the winning plans match the default engine's
    within cross-engine tolerance."""
    from repro.fleet.policy import FlexiblePolicy, _engine_for

    assert _engine_for("ftr", "jax") == "jax"
    assert _engine_for("shah", "jax") == "batched"
    assert _engine_for("rctree", "jax") == "scalar"
    assert _engine_for("rctree", "batched") == "scalar"
    assert _engine_for("ftr", "auto") == "auto"

    params = _params(6, 3, interior=False)
    caps = _caps(23, 5, 6)
    pol_jax = FlexiblePolicy(("ftr", "fr", "rctree"), engine="jax")
    pol_def = FlexiblePolicy(("ftr", "fr", "rctree"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        plans_jax = pol_jax.plan_batch(caps, params)
    plans_def = pol_def.plan_batch(caps, params)
    assert len(plans_jax) == caps.shape[0]
    for pj, pd in zip(plans_jax, plans_def):
        assert pj.scheme == pd.scheme
        assert pj.time == pytest.approx(pd.time, rel=REL_TOL)
