"""Coded data plane (ISSUE 10): reads and repairs as real transfers.

Closed forms and invariants:

* scenario validation rejects impossible fan-in and trace-without-plane;
* the default path (``dataplane=False``) emits none of the new summary
  keys (the bitwise golden guard pins the values themselves);
* a solo trace-driven read over constant-capacity links completes in
  exactly ``alpha / c`` seconds and moves ``fanin * alpha * block_bytes``
  bytes;
* trace arrivals with too few healthy endpoints are dropped and counted;
  endpoint failure mid-read tears the read down and banks exactly the
  partially transferred bytes;
* every completed repair's coded blocks decode (``can_reconstruct``) and
  a full ``reconstruct`` over k nodes round-trips the original file;
* wire-byte conservation: per repair, the done-fraction ledger sums to
  the plan totals for uninterrupted repairs and to strictly partial
  bytes for aborted segments, while ``work_accounting``'s
  banked + outstanding == plan-total triple holds at every epoch;
* chunked trace generation is chunk-size invariant;
* tracing a dataplane run never perturbs it, and the new event
  vocabulary round-trips through the Chrome converter and the report
  analyses (including the no-header ``repair_block`` fallback);
* the GF(2^8) kernel wrapper falls back to the pure-jnp reference with
  one warning when Pallas is unavailable (CPU-safe coding plane).
"""
import dataclasses
import json
import math
import warnings

import numpy as np
import pytest

from repro.coding.gf import GF8
from repro.core import CodeParams
from repro.fleet import (FleetSimulator, FlexiblePolicy, ReadTrace,
                         Scenario, generate_trace, simulate)
from repro.obs.report import link_bytes, top_links_by_bytes
from repro.obs.trace import chrome_trace

PARAMS = CodeParams.msr(n=6, k=2, d=3, M=4.0)   # alpha=2; mini-store scale 1


def _const_caps(n: int, c: float):
    caps = np.full((n, n), c)
    np.fill_diagonal(caps, 0.0)
    return lambda rng, m: caps.copy()


# ---------------------------------------------------------------------------
# 1. Scenario validation
# ---------------------------------------------------------------------------

def test_fanin_must_not_exceed_live_helpers():
    with pytest.raises(ValueError, match="read_fanin"):
        Scenario(num_nodes=4, duration=10.0, dataplane=True, read_fanin=4)
    # same fan-in without the data plane stays legal (phantom reads never
    # transfer fragments, so the bound is a data-plane concern)
    Scenario(num_nodes=4, duration=10.0, read_fanin=4)


def test_read_trace_requires_dataplane():
    with pytest.raises(ValueError, match="read_trace"):
        Scenario(num_nodes=6, duration=10.0,
                 read_trace=ReadTrace(rate=1.0))


def test_read_trace_needs_exactly_one_source():
    with pytest.raises(ValueError):
        ReadTrace()
    with pytest.raises(ValueError):
        ReadTrace(path="x.jsonl", rate=1.0)


def test_dataplane_blocks_must_divide_by_k():
    sc = Scenario(num_nodes=6, duration=10.0, dataplane=True,
                  dataplane_blocks=5)
    with pytest.raises(ValueError, match="divisible"):
        FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=0)


def test_bad_matmul_mode_rejected():
    with pytest.raises(ValueError, match="dataplane_matmul"):
        Scenario(num_nodes=6, duration=10.0, dataplane=True,
                 dataplane_matmul="cuda")


# ---------------------------------------------------------------------------
# 2. Default path emits no dataplane keys
# ---------------------------------------------------------------------------

def test_default_path_has_no_dataplane_keys():
    sc = Scenario(num_nodes=6, duration=50.0, failure_rate=2e-3,
                  capacity_model=_const_caps(6, 4.0))
    summary = simulate(sc, FlexiblePolicy(), PARAMS, seed=0)
    for key in ("repair_bytes", "read_bytes", "reads_completed",
                "reads_dropped", "decode_checks", "read_p50", "read_p99"):
        assert key not in summary, key


# ---------------------------------------------------------------------------
# 3. Closed-form read latency and bytes
# ---------------------------------------------------------------------------

def test_solo_trace_read_closed_form(tmp_path):
    """One read, no contention: latency == alpha/c, bytes == fanin*alpha*bb."""
    p = tmp_path / "one.jsonl"
    p.write_text('{"t": 1.0}\n')
    sc = Scenario(num_nodes=6, duration=10.0, failure_rate=0.0,
                  capacity_model=_const_caps(6, 4.0), dataplane=True,
                  read_trace=ReadTrace(path=str(p)))
    m = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=3).run()
    assert m.reads_completed == 1 and m.reads_dropped == 0
    # fanin = k = 2 fragments of alpha = 2 blocks over capacity-4 links
    assert m.read_latencies == [pytest.approx(2.0 / 4.0)]
    want_bytes = 2 * 2.0 * sc.dataplane_block_bytes
    assert m.read_bytes == pytest.approx(want_bytes)
    s = m.summary()
    assert s["read_p50"] == pytest.approx(0.5)
    assert s["read_p99"] == pytest.approx(0.5)


def test_trace_read_drop_when_too_few_healthy(tmp_path):
    """With fanin == len(healthy) - 0 endpoints free, arrivals drop."""
    p = tmp_path / "reads.jsonl"
    p.write_text('{"t": 2.0}\n')
    sc = Scenario(num_nodes=4, duration=60.0, failure_rate=0.0,
                  failures=((1.0, 0),),
                  capacity_model=_const_caps(4, 0.1), dataplane=True,
                  read_fanin=3, read_trace=ReadTrace(path=str(p)))
    m = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=1).run()
    # the capacity-0.1 links keep node 0's repair running well past t=2.0,
    # so at the arrival 3 healthy == fanin and the read cannot pick fanin
    # sources plus a distinct destination -> dropped
    assert m.reads_dropped == 1 and m.reads_completed == 0


def test_endpoint_failure_tears_down_read_and_banks_partial(tmp_path):
    p = tmp_path / "reads.jsonl"
    p.write_text('{"t": 0.5}\n')
    sc = Scenario(num_nodes=4, duration=60.0, failure_rate=0.0,
                  failures=((1.0, 2),),
                  capacity_model=_const_caps(4, 0.5), dataplane=True,
                  read_fanin=3, read_trace=ReadTrace(path=str(p)))
    sim = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=1)
    m = sim.run()
    # fanin=3 sources + 1 destination = all 4 nodes, so the t=1.0 failure
    # is always a read endpoint: the read tears down, never completes
    assert m.reads_torn_down == 1 and m.reads_completed == 0
    # solo nominal = alpha/c = 2/0.5 = 4s; 0.5s in -> done = 1/8 of each
    # of the 3 fragments' 2 blocks
    partial = (0.5 / 4.0) * 3 * 2.0 * sc.dataplane_block_bytes
    assert m.read_bytes == pytest.approx(partial)
    assert sum(sim.dataplane.read_link_bytes.values()) == \
        pytest.approx(partial)


# ---------------------------------------------------------------------------
# 4. Coded store: decode verification + full reconstruct round-trip
# ---------------------------------------------------------------------------

def test_repairs_decode_and_reconstruct_roundtrip():
    sc = Scenario(num_nodes=6, duration=400.0, failure_rate=0.0,
                  failures=((5.0, 0), (60.0, 3), (120.0, 1)),
                  capacity_model=_const_caps(6, 4.0), dataplane=True,
                  dataplane_verify=True)
    sim = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=7)
    m = sim.run()
    assert m.completed == 3
    assert m.decode_checks == 3 and m.decode_failures == 0
    # the regenerated store still reconstructs the original file from k
    # nodes, including a regenerated one
    dp = sim.dataplane
    M = int(dp.mini.M)
    combo = [dp.store.nodes[i] for i in (0, 3)]     # both were regenerated
    got = dp.store.rl.reconstruct(combo, M)
    np.testing.assert_array_equal(got, dp.store.file_blocks)


def test_matmul_backends_agree():
    """The kernel-backed GF matmul must not change the coded store's
    results vs the log/antilog tables (same rng stream, same blocks)."""
    base = Scenario(num_nodes=6, duration=60.0, failure_rate=0.0,
                    failures=((5.0, 0),),
                    capacity_model=_const_caps(6, 4.0), dataplane=True,
                    dataplane_verify=True)
    stores = []
    for mode in ("numpy", "kernel"):
        sc = dataclasses.replace(base, dataplane_matmul=mode)
        sim = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=7)
        sim.run()
        stores.append(sim.dataplane.store)
    for i in stores[0].nodes:
        np.testing.assert_array_equal(stores[0].nodes[i].vectors,
                                      stores[1].nodes[i].vectors)
        np.testing.assert_array_equal(stores[0].nodes[i].payload,
                                      stores[1].nodes[i].payload)


# ---------------------------------------------------------------------------
# 5. Wire-byte conservation
# ---------------------------------------------------------------------------

def test_uninterrupted_repair_bytes_equal_plan_total():
    """No aborts: the done-fraction ledger must sum to exactly the plan's
    per-link flows (the ``repair_block`` events carry those totals)."""
    sc = Scenario(num_nodes=6, duration=200.0, failure_rate=0.0,
                  failures=((5.0, 0),),
                  capacity_model=_const_caps(6, 4.0), dataplane=True,
                  trace=True)
    sim = FleetSimulator(sc, FlexiblePolicy(), PARAMS, seed=11)
    m = sim.run()
    assert m.completed == 1 and m.aborted == 0
    blocks = [e for e in sim.recorder.events if e["ev"] == "repair_block"]
    assert blocks
    assert m.repair_bytes == pytest.approx(sum(e["bytes"] for e in blocks))


def test_bytes_conserved_across_aborts_and_carryover():
    """Per-repair ledger == plan total for completed-clean repairs,
    strictly partial for aborted segments; global ledger == the sum; and
    the banked + outstanding == plan-total triple holds every epoch."""
    caps = np.full((8, 8), 2.0)
    np.fill_diagonal(caps, 0.0)
    sc = Scenario(num_nodes=8, duration=600.0, failure_rate=0.0,
                  failures=((1.0, 0), (4.0, 1), (8.0, 2)),
                  capacity_model=lambda rng, m: caps.copy(),
                  carryover=True, trace=True)
    params = CodeParams.msr(n=8, k=2, d=4, M=40.0)
    sc = dataclasses.replace(sc, dataplane=True)
    sim = FleetSimulator(sc, FlexiblePolicy(), params, seed=5)
    per_rid = {}
    orig = sim.dataplane.account_repair_wire

    def spy(r, done):
        if done > 0.0:
            per_rid[r.rid] = per_rid.get(r.rid, 0.0) + \
                done * sum(f for _, f in r.links) * sim.dataplane.block_bytes
        orig(r, done)

    sim.dataplane.account_repair_wire = spy
    sim.start()
    while True:
        for r in sim.active:
            for link, (banked, out, total) in r.work_accounting().items():
                assert banked + out == pytest.approx(total), (r.rid, link)
        if not sim.step():
            break
    m = sim.finish()
    assert m.completed >= 2 and m.repair_bytes > 0
    assert m.repair_bytes == pytest.approx(sum(per_rid.values()))
    events = sim.recorder.events
    aborted = {e["rid"] for e in events if e["ev"] == "repair_abort"}
    for e in events:
        if e["ev"] != "repair_complete":
            continue
        rid = e["rid"]
        plan_total = sum(b["bytes"] for b in events
                         if b["ev"] == "repair_block" and b["rid"] == rid)
        if rid in aborted:
            # carryover: banked blocks are never re-sent, so the wire
            # moved strictly less than the full plan
            assert 0.0 < per_rid[rid] < plan_total + 1e-6, rid
        else:
            assert per_rid[rid] == pytest.approx(plan_total), rid


# ---------------------------------------------------------------------------
# 6. Trace generation
# ---------------------------------------------------------------------------

def test_generate_trace_chunk_invariant(tmp_path):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    n1 = generate_trace(str(a), rate=5.0, duration=40.0, seed=9, chunk=8)
    n2 = generate_trace(str(b), rate=5.0, duration=40.0, seed=9,
                        chunk=65536)
    assert n1 == n2 > 100
    assert a.read_text() == b.read_text()
    ts = [json.loads(ln)["t"] for ln in a.read_text().splitlines()]
    assert len(ts) == n1
    assert all(x < y for x, y in zip(ts, ts[1:]))
    assert ts[-1] <= 40.0


def test_generate_trace_validates_inputs(tmp_path):
    with pytest.raises(ValueError):
        generate_trace(str(tmp_path / "x.jsonl"), rate=0.0, duration=1.0)
    with pytest.raises(ValueError):
        generate_trace(str(tmp_path / "x.jsonl"), rate=1.0, duration=0.0)


# ---------------------------------------------------------------------------
# 7. Observability: vocabulary, traced == untraced, report analyses
# ---------------------------------------------------------------------------

def _dataplane_scenario(trace: bool, tmp_path) -> Scenario:
    p = tmp_path / "w.jsonl"
    if not p.exists():
        generate_trace(str(p), rate=0.05, duration=300.0, seed=2)
    return Scenario(num_nodes=6, duration=300.0, failure_rate=0.0,
                    failures=((5.0, 0), (90.0, 3)),
                    capacity_model=_const_caps(6, 4.0), dataplane=True,
                    dataplane_verify=True, trace=trace,
                    read_trace=ReadTrace(path=str(p)))


def test_tracing_never_perturbs_the_dataplane(tmp_path):
    untraced = FleetSimulator(_dataplane_scenario(False, tmp_path),
                              FlexiblePolicy(), PARAMS, seed=13).run()
    traced_sim = FleetSimulator(_dataplane_scenario(True, tmp_path),
                                FlexiblePolicy(), PARAMS, seed=13)
    traced = traced_sim.run()
    assert traced.summary() == untraced.summary()
    kinds = {e["ev"] for e in traced_sim.recorder.events}
    assert {"read_queued", "read_complete", "repair_block"} <= kinds


def test_chrome_and_report_round_trip(tmp_path):
    sim = FleetSimulator(_dataplane_scenario(True, tmp_path),
                         FlexiblePolicy(), PARAMS, seed=13)
    m = sim.run()
    sim.finish()
    assert m.reads_completed > 0
    trace = sim.recorder.to_chrome()
    reads_closed = [e for e in trace["traceEvents"]
                    if e.get("ph") == "e" and e.get("cat") == "read"
                    and e.get("args", {}).get("reason") == "complete"]
    assert len(reads_closed) == m.reads_completed
    # read spans must never pollute the repair category (check_trace.py
    # counts cat=="repair" ends against completed + aborted)
    assert all(e.get("cat") != "repair" for e in reads_closed)
    header, events = sim.recorder.header(), sim.recorder.events
    top = top_links_by_bytes(header, events, 5)
    assert top
    assert header["meta"]["dataplane"]["links"]
    best = top[0][1]
    assert best["repair_bytes"] + best["read_bytes"] > 0
    # fallback path: no header snapshot -> repair bytes re-summed from
    # the repair_block events themselves
    fb = link_bytes({"meta": {}}, events)
    assert fb
    want = sum(e["bytes"] for e in events if e["ev"] == "repair_block")
    assert sum(c["repair_bytes"] for c in fb.values()) == \
        pytest.approx(want)


# ---------------------------------------------------------------------------
# 8. CPU-safe kernel fallback (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def test_gf_matmul_falls_back_to_reference_with_one_warning(monkeypatch):
    from repro.kernels import ops

    rng = np.random.default_rng(42)
    a = rng.integers(0, 256, (5, 7), dtype=np.uint8)
    b = rng.integers(0, 256, (7, 9), dtype=np.uint8)
    want = GF8.matmul(a, b)

    def boom(*args, **kwargs):
        raise RuntimeError("no pallas lowering on this host")

    monkeypatch.setattr(ops, "_padded_call", boom)
    monkeypatch.setitem(ops._fallback, "active", False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out1 = np.asarray(ops.gf_matmul(a, b))
        out2 = np.asarray(ops.gf_matmul(a, b))   # latched: no second warn
    np.testing.assert_array_equal(out1, want)
    np.testing.assert_array_equal(out2, want)
    runtime = [w for w in caught if w.category is RuntimeWarning]
    assert len(runtime) == 1, "fallback must warn exactly once"
    assert "falling back" in str(runtime[0].message)
    # reset the process-wide latch so later tests take the kernel path
    monkeypatch.setitem(ops._fallback, "active", False)
