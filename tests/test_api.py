"""Unified planner API (repro.core.api): registry round-trip, engine
resolution, plan()/plan_many() equivalence with the scalar oracle for every
registered scheme, kwarg forwarding, and the deprecation shims that keep
the legacy SCHEMES / BATCHED_SCHEMES / plan_batch imports alive.
"""
import math
import random
import warnings

import numpy as np
import pytest

from repro.core import (CodeParams, OverlayNetwork, RepairPlan, caps_tensor,
                        get_scheme, plan, plan_many, plans_from_batch,
                        register_scheme, scheme_names, unregister_scheme)
from repro.core import api


def _nets(seed: int, count: int, d: int, lo=10.0, hi=120.0):
    rng = random.Random(seed)
    out = []
    for _ in range(count):
        cap = [[0.0] * (d + 1) for _ in range(d + 1)]
        for u in range(d + 1):
            for v in range(d + 1):
                if u != v:
                    cap[u][v] = rng.uniform(lo, hi)
        out.append(OverlayNetwork(cap))
    return out


def _param_points():
    M, k, d, n = 600.0, 3, 6, 12
    return [
        ("msr", CodeParams.msr(n=n, k=k, d=d, M=M)),
        ("interior", CodeParams(n=n, k=k, d=d, M=M, alpha=230.0)),
    ]


PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)


# ---------------------------------------------------------------------------
# plan() / plan_many() vs the scalar oracle, for every registered scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point,params", _param_points())
@pytest.mark.parametrize("scheme", scheme_names())
def test_plan_many_matches_scalar_oracle(scheme, point, params):
    """plan_many (engine='auto') must agree with the per-network scalar
    planner on time AND traffic for every scheme in the registry, report
    the engine the registry declares, and never warn on the auto path."""
    nets = _nets(seed=len(scheme) + ord(point[0]), count=10, d=params.d)
    spec = get_scheme(scheme)
    scalar = [spec.scalar(net, params) for net in nets]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)  # auto never warns
        res = plan_many(caps_tensor(nets), params, scheme)
    np.testing.assert_allclose(res.times, [p.time for p in scalar],
                               rtol=1e-9, atol=1e-6,
                               err_msg=f"{scheme}@{point}: time mismatch")
    np.testing.assert_allclose(res.traffic, [p.total_traffic for p in scalar],
                               rtol=1e-9, atol=1e-6,
                               err_msg=f"{scheme}@{point}: traffic mismatch")
    assert res.engine == ("batched" if spec.batched is not None else "scalar")
    # and plan() with the default engine IS the scalar oracle
    p0 = plan(nets[0], params, scheme)
    assert p0.time == scalar[0].time
    assert p0.total_traffic == scalar[0].total_traffic


@pytest.mark.parametrize("scheme", scheme_names(batched=True))
def test_plan_single_network_through_batched_engine(scheme):
    """plan(engine='batched') routes a B=1 batch through the vectorized
    planner and materializes the same plan the batch reports."""
    net = _nets(seed=31, count=1, d=PARAMS.d)[0]
    pb = plan(net, PARAMS, scheme, engine="batched")
    ps = plan(net, PARAMS, scheme, engine="scalar")
    assert pb.time == pytest.approx(ps.time, rel=1e-9, abs=1e-6)
    assert pb.total_traffic == pytest.approx(ps.total_traffic,
                                             rel=1e-9, abs=1e-6)
    pb.validate(net)


def test_plan_shah_batch_is_bitwise_scalar():
    """The vectorized shah planner mirrors the scalar one's sequential
    float arithmetic exactly — equality, not allclose."""
    for point, params in _param_points():
        nets = _nets(seed=17, count=25, d=params.d)
        res = plan_many(caps_tensor(nets), params, "shah", engine="batched")
        for i, net in enumerate(nets):
            sp = plan(net, params, "shah", engine="scalar")
            assert res.times[i] == sp.time, (point, i)
            assert res.betas[i].tolist() == sp.betas, (point, i)
    # infeasible overlay: scalar contract is inf time, zero traffic
    zero = OverlayNetwork.star_only([0.0] * PARAMS.d)
    r = plan_many(caps_tensor([zero]), PARAMS, "shah", engine="batched")
    s = plan(zero, PARAMS, "shah", engine="scalar")
    assert math.isinf(r.times[0]) and math.isinf(s.time)
    assert r.traffic[0] == 0.0 == s.total_traffic


def test_plan_forwards_scheme_specific_kwargs():
    """Extra kwargs (shah's beta_max) pass through both entry points."""
    net = _nets(seed=5, count=1, d=PARAMS.d)[0]
    bmax = 0.6 * PARAMS.alpha
    direct = plan(net, PARAMS, "shah", beta_max=bmax)
    batched = plan_many(caps_tensor([net]), PARAMS, "shah",
                        engine="batched", beta_max=bmax)
    assert batched.times[0] == direct.time
    assert direct.time != plan(net, PARAMS, "shah").time  # kwarg had effect


def test_witness_kwarg_reaches_only_declaring_schemes():
    """witness= is forwarded to exactly the schemes that declared
    accepts_witness (they validate it eagerly) and dropped for the rest."""
    net = _nets(seed=3, count=1, d=PARAMS.d)[0]
    caps = caps_tensor([net])
    for scheme in ("fr", "ftr"):
        assert get_scheme(scheme).accepts_witness
        with pytest.raises(ValueError, match="unknown witness engine"):
            plan(net, PARAMS, scheme, witness="bogus")
        with pytest.raises(ValueError, match="unknown witness engine"):
            plan_many(caps, PARAMS, scheme, witness="bogus")
    for scheme in ("star", "tr", "shah", "rctree"):
        assert not get_scheme(scheme).accepts_witness
        plan(net, PARAMS, scheme, witness="bogus")          # silently dropped
        plan_many(caps, PARAMS, scheme, witness="bogus")


def test_unknown_scheme_and_engine_errors():
    net = _nets(seed=1, count=1, d=PARAMS.d)[0]
    with pytest.raises(ValueError, match="registered schemes"):
        plan(net, PARAMS, "bogus")
    with pytest.raises(ValueError, match="registered schemes"):
        plan_many(caps_tensor([net]), PARAMS, "bogus")
    with pytest.raises(ValueError, match="unknown engine"):
        plan(net, PARAMS, "star", engine="warp")
    with pytest.raises(ValueError, match="unknown engine"):
        plan_many(caps_tensor([net]), PARAMS, "star", engine="warp")


# ---------------------------------------------------------------------------
# Registry round-trip and the declared scalar fallback
# ---------------------------------------------------------------------------

def test_registry_round_trip():
    """register (as a decorator) -> list -> capability flags -> dispatch
    -> unregister."""
    from repro.core import SCHEMES, BATCHED_SCHEMES

    @register_scheme("_test_dummy", topology="star",
                     description="test-only delegate to star")
    def plan_dummy(net, params, **kw):
        return plan(net, params, "star")

    try:
        assert "_test_dummy" in scheme_names()
        assert "_test_dummy" in scheme_names(batched=False)
        assert "_test_dummy" not in scheme_names(batched=True)
        assert "_test_dummy" in scheme_names(topology="star")
        spec = get_scheme("_test_dummy")
        assert spec.scalar is plan_dummy
        assert spec.batched is None
        assert not spec.accepts_witness and not spec.produces_tree

        nets = _nets(seed=8, count=4, d=PARAMS.d)
        p = plan(nets[0], PARAMS, "_test_dummy")
        assert isinstance(p, RepairPlan)
        res = plan_many(caps_tensor(nets), PARAMS, "_test_dummy")
        assert res.engine == "scalar"
        assert len(res.plans) == len(nets)
        assert plans_from_batch(res, PARAMS) == res.plans

        # the legacy dict views are live: the new scheme shows up at once
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert SCHEMES["_test_dummy"] is plan_dummy
            assert "_test_dummy" not in BATCHED_SCHEMES

        with pytest.raises(ValueError, match="already registered"):
            register_scheme("_test_dummy", plan_dummy)
    finally:
        unregister_scheme("_test_dummy")
    assert "_test_dummy" not in scheme_names()
    with pytest.raises(ValueError, match="registered schemes"):
        get_scheme("_test_dummy")


def test_builtin_capability_flags():
    """The paper's family is registered with the capabilities the planners
    actually have."""
    assert scheme_names() == ("star", "fr", "tr", "ftr", "shah", "rctree")
    assert scheme_names(batched=True) == ("star", "fr", "tr", "ftr", "shah")
    assert scheme_names(topology="tree") == ("tr", "ftr", "rctree")
    assert get_scheme("rctree").batched is None     # declared, not discovered
    assert {s for s in scheme_names() if get_scheme(s).accepts_witness} \
        == {"fr", "ftr"}


def test_explicit_batched_request_warns_once_then_falls_back():
    """engine='batched' on a scalar-only scheme warns once per scheme per
    process and plans on the scalar path; engine='auto' never warns."""
    nets = _nets(seed=23, count=3, d=PARAMS.d)
    caps = caps_tensor(nets)
    api._warned_scalar_fallback.discard("rctree")
    with pytest.warns(RuntimeWarning,
                      match="no batched planner registered for 'rctree'"):
        res = plan_many(caps, PARAMS, "rctree", engine="batched")
    assert res.engine == "scalar"
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)   # second call silent
        again = plan_many(caps, PARAMS, "rctree", engine="batched")
    assert again.engine == "scalar"


def test_scalar_fallback_preserves_rctree_flows():
    """rctree's fixed-beta-per-edge flows are NOT tree_flows(parents, betas);
    the fallback batch must hand back the original scalar plans verbatim."""
    nets = _nets(seed=29, count=3, d=PARAMS.d)
    res = plan_many(caps_tensor(nets), PARAMS, "rctree")
    plans = plans_from_batch(res, PARAMS)
    for net, got in zip(nets, plans):
        want = get_scheme("rctree").scalar(net, PARAMS)
        assert got.parent == want.parent
        assert got.flows == want.flows
        assert got.time == want.time


def test_compare_schemes_batched_covers_shah_without_fallback():
    """Acceptance: compare_schemes over the star family incl. shah at
    engine='batched' reports engine='batched' everywhere, with no
    fallback warning, and agrees with the scalar oracle."""
    from repro.storage import compare_schemes, uniform

    family = ("star", "fr", "tr", "ftr", "shah")
    params = CodeParams.msr(n=20, k=5, d=6, M=1000.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        stats = compare_schemes(params, uniform(), family, trials=6,
                                seed=3, engine="batched")
    assert [stats[s].engine for s in family] == ["batched"] * len(family)
    scalar = compare_schemes(params, uniform(), family, trials=6,
                             seed=3, engine="scalar")
    for s in family:
        assert stats[s].mean_time == pytest.approx(scalar[s].mean_time,
                                                   rel=1e-9)
        assert stats[s].mean_traffic == pytest.approx(
            scalar[s].mean_traffic, rel=1e-9)
        assert stats[s].mean_norm_time == pytest.approx(
            scalar[s].mean_norm_time, rel=1e-9)


def test_policy_specs_validate_against_registry():
    """Fleet policy specs resolve through the registry, with errors that
    list what is registered."""
    from repro.fleet import FixedPolicy, FlexiblePolicy, make_policy

    with pytest.raises(ValueError, match="registered schemes"):
        FixedPolicy("bogus")
    with pytest.raises(ValueError, match="registered schemes"):
        make_policy("bogus")
    with pytest.raises(ValueError, match="registered schemes"):
        FlexiblePolicy(("ftr", "bogus"))
    # scalar-only schemes are valid flexible candidates since the
    # mixed-engine path: rctree simply loops the scalar oracle.
    assert FlexiblePolicy(("ftr", "rctree")).schemes == ("ftr", "rctree")
    assert make_policy("rctree").name == "rctree"   # scalar-only is fine
    assert make_policy("flexible").name == "flexible"


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------

def test_legacy_scheme_maps_warn_once_and_stay_live():
    from repro.core import BATCHED_SCHEMES, SCHEMES
    from repro.core.batched import plan_shah_batch
    from repro.core.star import plan_star

    api._deprecation_warned.discard("SCHEMES")
    with pytest.warns(DeprecationWarning, match="SCHEMES is deprecated"):
        assert SCHEMES["star"] is plan_star
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)  # exactly once
        assert "rctree" in SCHEMES
        assert sorted(SCHEMES) == sorted(scheme_names())

    api._deprecation_warned.discard("BATCHED_SCHEMES")
    with pytest.warns(DeprecationWarning,
                      match="BATCHED_SCHEMES is deprecated"):
        assert BATCHED_SCHEMES["shah"] is plan_shah_batch
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert "rctree" not in BATCHED_SCHEMES
        assert sorted(BATCHED_SCHEMES) == sorted(scheme_names(batched=True))


def test_plan_batch_shim_forwards_kwargs_and_warns_once():
    """Satellite fix: witness= (any per-scheme kwarg) now passes through
    plan_batch, which used to swallow the signature entirely."""
    from repro.core import plan_batch

    nets = _nets(seed=41, count=4, d=PARAMS.d)
    caps = caps_tensor(nets)
    api._deprecation_warned.discard("plan_batch")
    with pytest.warns(DeprecationWarning, match="plan_batch is deprecated"):
        res = plan_batch(caps, PARAMS, "fr")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)  # exactly once
        # kwargs are forwarded: fr validates the witness engine eagerly
        with pytest.raises(ValueError, match="unknown witness engine"):
            plan_batch(caps, PARAMS, "fr", witness="bogus")
        res2 = plan_batch(caps, PARAMS, "fr", witness="exact")
        # schemes declared scalar-only keep the historical ValueError
        with pytest.raises(ValueError, match="no batched planner"):
            plan_batch(caps, PARAMS, "rctree")
    np.testing.assert_array_equal(res.times, res2.times)
