"""Property-based tests (hypothesis) for the regeneration planners.

System invariants checked on random heterogeneous networks:
  * every scheme's plan is structurally valid (tree, Theorem-3/5 flows);
  * multi-round repair histories keep the MDS property (min-cut >= M) for
    STAR/FR/TR/FTR — and the scheme ordering FTR <= min(FR, TR) <= STAR;
  * FR closed form at MSR matches the bisection LP optimum;
  * heuristics are lower-bounded by the exact brute-force ORT optimum;
  * fractional-beta ceil-rounding keeps the region constraints (III-C).
"""
import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CodeParams, InfoFlowGraph, OverlayNetwork,
                        event_from_plan, fr_closed_form_msr, heuristic_region,
                        msr_region, plan_fr, plan_ftr, plan_ort_uniform,
                        plan_shah, plan_star, plan_time, plan_tr, sigma,
                        theorem6_example, uniform_beta)
from repro.core.lp import minmax_time_star
from repro.core.tree import tree_time_uniform


def rand_net(rng: random.Random, d: int, lo=10.0, hi=120.0) -> OverlayNetwork:
    cap = [[0.0] * (d + 1) for _ in range(d + 1)]
    for u in range(d + 1):
        for v in range(d + 1):
            if u != v:
                cap[u][v] = rng.uniform(lo, hi)
    return OverlayNetwork(cap)


nets = st.builds(
    lambda seed, d: (rand_net(random.Random(seed), d), d),
    st.integers(0, 10_000), st.integers(4, 7))


@settings(max_examples=20, deadline=None)
@given(nets, st.integers(2, 4))
def test_single_round_all_schemes_valid_and_ordered(net_d, k):
    net, d = net_d
    if k > d - 1:
        k = d - 1
    p = CodeParams.msr(n=d + 2, k=k, d=d, M=float(k * (d - k + 1) * 12))
    s, f, t, ft = plan_star(net, p), plan_fr(net, p), plan_tr(net, p), plan_ftr(net, p)
    for pl in (s, f, t, ft):
        pl.validate(net)
        assert pl.time < math.inf
    assert f.time <= s.time * (1 + 1e-9)
    assert t.time <= s.time * (1 + 1e-9)
    assert ft.time <= min(f.time, t.time) * (1 + 1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 3), st.integers(1, 3))
def test_multi_round_mds(seed, k, rounds):
    """Cascading repairs (the Lemma-2 worst case) keep min-cut >= M."""
    rng = random.Random(seed)
    d = rng.randint(k + 1, 5)
    n = d + 2
    p = CodeParams.msr(n=n, k=k, d=d, M=float(k * (d - k + 1) * 6))
    g = InfoFlowGraph(p, initial_nodes=list(range(1, n + 1)))
    planner = rng.choice([plan_star, plan_fr, plan_tr, plan_ftr])
    next_id = n + 1
    for _ in range(rounds):
        failed = rng.choice(g.live)
        providers = rng.sample([x for x in g.live if x != failed], d)
        net = rand_net(rng, d)
        plan = planner(net, p)
        g.fail_and_repair(failed, event_from_plan(plan, next_id, providers))
        next_id += 1
    worst, flow = g.worst_collector()
    assert flow >= p.M - 1e-6, (planner.__name__, worst, flow, p)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_fr_closed_form_matches_lp(seed, k):
    rng = random.Random(seed)
    d = rng.randint(k, 8)
    p = CodeParams.msr(n=d + 2, k=k, d=d, M=float(k * (d - k + 1) * 10))
    caps = [rng.uniform(1.0, 120.0) for _ in range(d)]
    betas = fr_closed_form_msr(caps, p)
    t_closed = max(b / c for b, c in zip(betas, caps))
    t_lp = minmax_time_star(caps, msr_region(p), p.alpha)
    assert t_closed == pytest.approx(t_lp, rel=1e-6)
    assert sigma(1, betas, k, d) == pytest.approx(p.M / k, rel=1e-9)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_tr_heuristic_vs_exact_ort(seed):
    rng = random.Random(seed)
    d = rng.randint(3, 5)
    k = rng.randint(2, d - 1)
    p = CodeParams.msr(n=d + 2, k=k, d=d, M=float(k * (d - k + 1) * 4))
    net = rand_net(rng, d)
    heur = plan_tr(net, p)
    exact = plan_ort_uniform(net, p)
    assert heur.time >= exact.time * (1 - 1e-9)
    # the heuristic should be reasonably close on tiny instances
    assert heur.time <= exact.time * 2.0 + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_non_msr_heuristic_region_and_rounding(seed):
    """alpha > M/k: FR beats STAR, uniform point is in the region, and
    ceil-rounding the LP solution stays in the region (Section III-C)."""
    rng = random.Random(seed)
    k = rng.randint(2, 4)
    d = rng.randint(k + 1, 7)
    M = float(k * (d - k + 1) * 20)
    alpha_msr = M / k
    alpha = alpha_msr * rng.uniform(1.05, 1.8)
    p = CodeParams(n=d + 2, k=k, d=d, M=M, alpha=alpha)
    region = heuristic_region(p)
    assert region.contains([p.beta] * d, tol=1e-9)
    assert region.is_feasible(p)
    net = rand_net(rng, d)
    fr = plan_fr(net, p)
    fr.validate(net)
    st_ = plan_star(net, p)
    assert fr.time <= st_.time * (1 + 1e-9)
    # integral blocks: rounding up each beta_i keeps every sigma_j threshold
    rounded = [math.ceil(b - 1e-9) for b in fr.betas]
    assert region.contains(rounded, tol=1e-9)


def test_theorem6_incomparable_regions():
    p, d1, d2 = theorem6_example()
    assert d1.is_feasible(p) and d2.is_feasible(p)
    b1, b2 = [0, 1, 4, 4], [0, 2, 2, 2]
    assert d1.contains(b1) and not d2.contains(b1)
    assert d2.contains(b2) and not d1.contains(b2)
    # the paper's capacity settings that flip the preference
    for caps, better in (((1, 1, 4, 4), b1), ((1, 2, 2, 2), b2)):
        t1 = max(b / c for b, c in zip(sorted(b1), sorted(caps)))
        t2 = max(b / c for b, c in zip(sorted(b2), sorted(caps)))
        tb = max(b / c for b, c in zip(sorted(better), sorted(caps)))
        assert tb == pytest.approx(min(t1, t2))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4))
def test_shah_baseline_dominated_by_fr(seed, k):
    """FR's region subsumes the (beta_max, gamma) region of [6], so FR is
    at least as fast (Section VII comparison)."""
    rng = random.Random(seed)
    d = rng.randint(k + 1, 8)
    p = CodeParams.msr(n=d + 2, k=k, d=d, M=float(k * (d - k + 1) * 10))
    net = rand_net(rng, d)
    fr, sh = plan_fr(net, p), plan_shah(net, p)
    sh.validate(net)
    assert fr.time <= sh.time * (1 + 1e-6)
    # Shah plans must also keep MDS (single round)
    g = InfoFlowGraph(p, initial_nodes=list(range(1, d + 3)))
    g.fail_and_repair(d + 2, event_from_plan(sh, d + 3, list(range(1, d + 1))))
    assert g.worst_collector()[1] >= p.M - 1e-6


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_waterfill_oracle_matches_lp(seed):
    """The water-fill (leximin) oracle and the scipy LP must agree on
    fixed-tree feasibility at any time t (exactness of the fast oracle)."""
    from repro.core.lp import tree_feasible_at_time, _subtree_sets
    rng = random.Random(seed)
    k = rng.randint(2, 4)
    d = rng.randint(k + 1, 8)
    msr = rng.random() < 0.5
    M = float(k * (d - k + 1) * 12)
    alpha = M / k if msr else M / k * rng.uniform(1.05, 1.6)
    p = CodeParams(n=d + 2, k=k, d=d, M=M, alpha=alpha)
    region = msr_region(p) if msr else heuristic_region(p)
    # random rooted tree
    parent = {}
    order = list(range(1, d + 1))
    rng.shuffle(order)
    placed = [0]
    for u in order:
        parent[u] = rng.choice(placed)
        placed.append(u)
    caps = {(u, pa): rng.uniform(1.0, 120.0) for u, pa in parent.items()}
    for t_mult in (0.3, 0.7, 1.0, 1.5, 3.0):
        t = t_mult * p.beta / max(caps.values())
        wf = tree_feasible_at_time(t, parent, caps, region, p.alpha)
        lp_w = tree_feasible_at_time(t, parent, caps, region, p.alpha,
                                     minimize_traffic=True, witness="lp")
        assert (wf is None) == (lp_w is None), (
            f"oracle disagreement at t={t}: wf={wf} lp={lp_w}")


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_uniform_beta_consistency(seed):
    """uniform_beta inverts the storage/bandwidth tradeoff equation."""
    rng = random.Random(seed)
    k = rng.randint(1, 6)
    d = rng.randint(k, 10)
    M = rng.uniform(10.0, 1000.0)
    # alpha between MSR and MBR
    a_msr = M / k
    a_mbr = 2.0 * M * d / (k * (2 * d - k + 1))
    alpha = a_msr + (a_mbr - a_msr) * rng.random()
    b = uniform_beta(M, k, d, alpha)
    total = sum(min((d - k + j) * b, alpha) for j in range(1, k + 1))
    assert total == pytest.approx(M, rel=1e-9)
