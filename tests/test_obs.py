"""Flight recorder, telemetry, and planner profiling (ISSUE 7 acceptance).

The load-bearing invariant: **tracing is observation, never perturbation**.
The simulator allocates its repair ids unconditionally (one integer, no
rng), and every emission sits behind ``if recorder is not None`` — so a
traced run must produce a bitwise-identical metrics summary to the
untraced run at the same seed, which the purity tests pin on both a quiet
steady scenario and the full mitigation stack (brownouts + watchdog +
evictions).  On top of that:

* span accounting — finished ``transfer`` spans in the Chrome export
  equal the metrics' ``completed + aborted``, repair ids are stable
  across abort/re-admission, and a no-contention single repair predicts
  its own realized time (plan_err == 0);
* link telemetry — per-link busy time and user-seconds integrate exactly
  for a closed-form single repair;
* ring buffer — a tiny ``trace_capacity`` drops oldest events, counts
  them, and still exports valid JSON;
* planner profiling — ``plan_many(..., profile=)`` records the declared
  fr/ftr stages without changing any planned value;
* the report module's analyses agree with the metrics counters.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core import CodeParams, mbr_point, plan_many
from repro.fleet import (SCENARIOS, FixedPolicy, FleetSimulator,
                         FlexiblePolicy, Scenario, make_policy, mitigated,
                         simulate)
from repro.obs import (FlightRecorder, LinkUsageTracer, PlannerProfile,
                       SCHEMA_VERSION, TRACE_KIND, chrome_trace,
                       finished_transfer_spans, json_sanitize)
from repro.obs.report import (load_jsonl, node_brownout_timeline,
                              plan_error_attribution, render_report,
                              top_bottleneck_links, watchdog_funnel)

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)


def _fixed_caps(n: int, seed: int = 0, lo: float = 10.0, hi: float = 120.0):
    caps = np.random.default_rng(seed).uniform(lo, hi, size=(n, n))
    np.fill_diagonal(caps, 0.0)
    return caps, (lambda rng, m: caps.copy())


def _first_providers(failed, healthy, rng):
    return [h for h in healthy if h != failed][:PARAMS.d]


def _traced(sc: Scenario, policy, seed: int = 0, **overrides):
    sim = FleetSimulator(
        dataclasses.replace(sc, trace=True, **overrides),
        policy, PARAMS, seed=seed)
    metrics = sim.run()
    return sim, metrics


# ---------------------------------------------------------------------------
# tracing is observation, never perturbation
# ---------------------------------------------------------------------------

def test_recorder_absent_by_default():
    sc = SCENARIOS["steady"](16, failure_rate=2e-3, duration=500.0)
    sim = FleetSimulator(sc, FixedPolicy("fr"), PARAMS, seed=0)
    assert sim.recorder is None and sim.link_tracer is None
    assert sim.shares.tracer is None


@pytest.mark.parametrize("kind,policy,seed", [
    ("steady", FixedPolicy("fr"), 0),
    ("stragglers", FlexiblePolicy(), 1),
])
def test_traced_summary_bitwise_equals_untraced(kind, policy, seed):
    sc = SCENARIOS[kind](16, failure_rate=4e-3, duration=1500.0)
    if kind == "stragglers":
        sc = mitigated(sc)     # watchdog + evictions + degraded-d on
    untraced = simulate(sc, policy, PARAMS, seed=seed)
    sim, metrics = _traced(sc, policy, seed=seed)
    assert metrics.summary() == untraced
    assert len(sim.recorder) > 0


def test_span_count_equals_completed_plus_aborted():
    sc = mitigated(SCENARIOS["stragglers"](16, failure_rate=4e-3,
                                           duration=1500.0))
    sim, metrics = _traced(sc, FlexiblePolicy(), seed=1)
    trace = sim.recorder.to_chrome()
    assert finished_transfer_spans(trace) == (metrics.completed
                                              + metrics.aborted)


# ---------------------------------------------------------------------------
# deterministic single-repair lifecycle
# ---------------------------------------------------------------------------

def _single_failure_sim():
    n = 10
    caps, model = _fixed_caps(n, seed=3)
    sc = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                  failures=((10.0, 0),), capacity_model=model,
                  provider_picker=_first_providers)
    return _traced(sc, FixedPolicy("star"))


def test_single_repair_event_sequence():
    sim, metrics = _single_failure_sim()
    assert metrics.completed == 1 and metrics.aborted == 0
    evs = sim.recorder.events
    names = [e["ev"] for e in evs]
    for needed in ("node_fail", "repair_queued", "repair_admitted",
                   "repair_complete", "node_repaired"):
        assert needed in names, f"missing {needed} in {names}"
    assert names.index("repair_queued") < names.index("repair_admitted") \
        < names.index("repair_complete")
    admitted = next(e for e in evs if e["ev"] == "repair_admitted")
    complete = next(e for e in evs if e["ev"] == "repair_complete")
    assert admitted["rid"] == complete["rid"]
    assert admitted["node"] == 0
    assert admitted["scheme"] == "star"
    assert admitted["d"] == PARAMS.d
    assert len(admitted["helpers"]) == PARAMS.d
    # no contention, perfect knowledge: the plan predicts its own time
    assert complete["realized"] == pytest.approx(metrics.regen_times[0])
    assert complete["plan_err"] == pytest.approx(0.0, abs=1e-9)
    assert complete["predicted"] == pytest.approx(complete["realized"])
    # the realized bottleneck is one of the plan's links
    src, dst = complete["bottleneck"]
    assert dst == 0 and src in admitted["helpers"]


def test_single_repair_link_conservation():
    sim, metrics = _single_failure_sim()
    duration = metrics.regen_times[0]
    snap = sim.recorder.meta["links"]
    # a star plan holds all d provider->newcomer links, each exactly one
    # user, for exactly the repair duration
    assert len(snap["links"]) == PARAMS.d
    for key, st in snap["links"].items():
        assert key.endswith("->0")
        assert st["busy_time"] == pytest.approx(duration)
        assert st["user_seconds"] == pytest.approx(duration)
        assert st["max_users"] == 1
    assert snap["total_user_seconds"] == pytest.approx(PARAMS.d * duration)
    # the acceptance inequality, tight here: user-seconds >= completed *
    # regen_mean
    assert snap["total_user_seconds"] >= metrics.completed * duration


def test_abort_keeps_rid_across_readmission():
    n = 10
    caps, model = _fixed_caps(n, seed=3)
    # node 1 is a provider of node 0's repair and fails mid-transfer
    sc = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                  failures=((10.0, 0), (11.0, 1)), capacity_model=model,
                  provider_picker=_first_providers)
    sim, metrics = _traced(sc, FixedPolicy("star"))
    assert metrics.aborted >= 1 and metrics.completed == 2
    evs = sim.recorder.events
    aborts = [e for e in evs if e["ev"] == "repair_abort"]
    assert aborts and aborts[0]["lost_provider"] == 1
    rid = aborts[0]["rid"]
    admissions = [e for e in evs
                  if e["ev"] == "repair_admitted" and e["rid"] == rid]
    assert len(admissions) == 2, "rid must survive abort -> re-admission"
    completes = [e for e in evs
                 if e["ev"] == "repair_complete" and e["rid"] == rid]
    assert len(completes) == 1
    assert finished_transfer_spans(sim.recorder.to_chrome()) == (
        metrics.completed + metrics.aborted)


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_ring_buffer_drops_oldest_and_still_exports():
    sc = SCENARIOS["steady"](16, failure_rate=4e-3, duration=1500.0)
    with pytest.warns(RuntimeWarning, match="ring buffer wrapped"):
        sim, metrics = _traced(sc, FixedPolicy("fr"), trace_capacity=8)
    rec = sim.recorder
    assert len(rec) <= 8
    assert rec.dropped > 0
    assert rec.header()["dropped"] == rec.dropped
    # the explicit alias consumers should prefer (ISSUE 8)
    assert rec.header()["dropped_events"] == rec.dropped
    # both exports stay valid strict JSON despite missing span begins
    for line in rec.to_jsonl().splitlines():
        json.loads(line)
    json.dumps(rec.to_chrome(), allow_nan=False)
    # and the purity invariant survives the tiny buffer
    untraced = simulate(sc, FixedPolicy("fr"), PARAMS, seed=0)
    assert metrics.summary() == untraced


def test_ring_wrap_warns_exactly_once():
    rec = FlightRecorder(capacity=2)
    with pytest.warns(RuntimeWarning) as record:
        for i in range(10):
            rec.emit(float(i), "x")
    wraps = [w for w in record
             if "ring buffer wrapped" in str(w.message)]
    assert len(wraps) == 1, "wrap warning must fire once, not per drop"
    assert rec.dropped == 8
    assert rec.header()["dropped_events"] == 8


def test_zero_capacity_rejected():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)
    sc = SCENARIOS["steady"](16, failure_rate=2e-3, duration=100.0)
    with pytest.raises(ValueError):
        dataclasses.replace(sc, trace=True, trace_capacity=0).__post_init__()


# ---------------------------------------------------------------------------
# json_sanitize / export formats
# ---------------------------------------------------------------------------

def test_json_sanitize():
    out = json_sanitize({
        "inf": math.inf, "ninf": -math.inf, "nan": math.nan,
        "np": np.float64(2.5), "npi": np.int64(7),
        "tup": (1.0, math.inf), 3: "intkey",
    })
    assert out == {"inf": None, "ninf": None, "nan": None, "np": 2.5,
                   "npi": 7, "tup": [1.0, None], "3": "intkey"}


def test_jsonl_round_trip(tmp_path):
    sim, metrics = _single_failure_sim()
    path = str(tmp_path / "trace.jsonl")
    sim.recorder.save_jsonl(path)
    header, events = load_jsonl(path)
    assert header["schema_version"] == SCHEMA_VERSION
    assert header["kind"] == TRACE_KIND
    assert header["events"] == len(events) == len(sim.recorder)
    assert header["meta"]["summary"]["completed"] == 1
    assert [e["ev"] for e in events] == [e["ev"]
                                         for e in sim.recorder.events]


def test_chrome_trace_schema():
    sim, _ = _single_failure_sim()
    trace = sim.recorder.to_chrome()
    assert trace["otherData"]["kind"] == TRACE_KIND
    open_spans = {}
    for e in trace["traceEvents"]:
        assert {"ph", "pid", "ts"} <= set(e), e
        if e["ph"] == "b":
            open_spans[(e["cat"], e["id"])] = e
        elif e["ph"] == "e":
            assert open_spans.pop((e["cat"], e["id"]), None) is not None
    assert not open_spans, "chrome_trace must close every span"


def test_chrome_trace_closes_unfinished_spans():
    # a repair queued but never admitted must still close at last_ts
    events = [{"t": 1.0, "ev": "repair_queued", "rid": 0, "node": 3},
              {"t": 2.0, "ev": "node_fail", "node": 3}]
    trace = chrome_trace(events)
    ends = [e for e in trace["traceEvents"] if e["ph"] == "e"]
    assert len(ends) == 2
    assert all(e["args"].get("unfinished") for e in ends)
    assert all(e["ts"] == 2.0 * 1e6 for e in ends)


# ---------------------------------------------------------------------------
# report analyses agree with the metrics
# ---------------------------------------------------------------------------

def test_report_against_metrics():
    sc = mitigated(SCENARIOS["stragglers"](16, failure_rate=4e-3,
                                           duration=1500.0))
    sim, metrics = _traced(sc, FlexiblePolicy(), seed=1)
    header, events = sim.recorder.header(), sim.recorder.events
    funnel = watchdog_funnel(events)
    assert funnel["flags"] == metrics.watchdog_flags
    assert funnel["replans"] == metrics.watchdog_replans
    assert funnel["evictions"] == metrics.evictions
    assert funnel["giveups"] == metrics.watchdog_giveups
    top = top_bottleneck_links(header, events, k=5)
    assert top and all(st["user_seconds"] >= 0 for _, st in top)
    assert top == sorted(top, key=lambda kv: -kv[1]["user_seconds"])
    brown = node_brownout_timeline(events, sc.duration)
    assert sum(len(c["episodes"]) for c in brown.values()) \
        == metrics.degrade_events
    attribution = plan_error_attribution(events)
    assert len(attribution) <= 10
    text = render_report(header, events)
    assert "bottleneck links" in text and "watchdog funnel" in text


def test_link_stats_fallback_matches_online_integrals():
    """With the header snapshot removed, reconstructing the per-link
    aggregates from link_users events must reproduce the tracer's online
    integrals (same information, two accumulators)."""
    sim, _ = _single_failure_sim()
    snap = sim.recorder.meta["links"]["links"]
    header = sim.recorder.header()
    header["meta"] = {"duration": 1000.0}
    derived = dict(top_bottleneck_links(header, sim.recorder.events, k=99))
    assert set(derived) == set(snap)
    for key in snap:
        assert derived[key]["busy_time"] == pytest.approx(
            snap[key]["busy_time"])
        assert derived[key]["user_seconds"] == pytest.approx(
            snap[key]["user_seconds"])
        assert derived[key]["max_users"] == snap[key]["max_users"]


# ---------------------------------------------------------------------------
# planner profiling
# ---------------------------------------------------------------------------

def _interior_params():
    M, k, d, n = 600.0, 3, 6, 12
    a_mbr, _ = mbr_point(M, k, d)
    return CodeParams(n=n, k=k, d=d, M=M,
                      alpha=0.5 * (M / k + a_mbr))


def _caps_batch(B=16, d=6, seed=0):
    rng = np.random.default_rng(seed)
    caps = rng.uniform(10.0, 120.0, size=(B, d + 1, d + 1))
    idx = np.arange(d + 1)
    caps[:, idx, idx] = 0.0
    return caps


def test_profile_records_declared_stages_without_changing_plans():
    caps = _caps_batch()
    params = _interior_params()
    for scheme, expect_stages in (
            ("fr", {"star_bisection", "witness"}),
            ("ftr", {"tr_seed", "candidates", "local_search",
                     "final_solve", "witness"})):
        bare = plan_many(caps, params, scheme, engine="batched")
        prof = PlannerProfile()
        profiled = plan_many(caps, params, scheme, engine="batched",
                             profile=prof)
        np.testing.assert_array_equal(bare.times, profiled.times)
        np.testing.assert_array_equal(bare.traffic, profiled.traffic)
        np.testing.assert_array_equal(bare.parents, profiled.parents)
        s = prof.summary()
        assert expect_stages <= set(s["stages"]), (scheme, s["stages"])
        assert "total" in s["stages"]
        assert s["counters"]["lanes"] == caps.shape[0]
        assert s["meta"]["scheme"] == scheme
        assert all(st["ms"] >= 0 and st["calls"] >= 1
                   for st in s["stages"].values())


def test_profile_msr_takes_closed_form():
    prof = PlannerProfile()
    plan_many(_caps_batch(), PARAMS, "fr", engine="batched", profile=prof)
    s = prof.summary()
    assert s["counters"]["closed_form_lanes"] == 16
    assert s["counters"]["bisection_lanes"] == 0
    assert "closed_form" in s["stages"]


def test_profile_scalar_engine_still_notes():
    prof = PlannerProfile()
    plan_many(_caps_batch(B=4), PARAMS, "fr", engine="scalar",
              profile=prof)
    s = prof.summary()
    assert s["meta"]["engine"] == "scalar"
    assert "total" in s["stages"]


def test_profile_stage_accumulates():
    prof = PlannerProfile()
    with prof.stage("a"):
        pass
    with prof.stage("a"):
        pass
    prof.count("widgets", 3)
    prof.count("widgets", 2)
    prof.note(hello="world")
    s = prof.summary()
    assert s["stages"]["a"]["calls"] == 2
    assert s["counters"]["widgets"] == 5
    assert s["meta"]["hello"] == "world"
