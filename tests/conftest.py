"""Shared test configuration.

Some test modules import ``hypothesis`` at the top level; CI installs it
(requirements-ci.txt) but minimal local environments may not have it.  Skip
collecting those modules instead of erroring the whole run — the seeded
non-hypothesis tests still provide coverage (e.g. tests/test_witness.py
keeps its deterministic sweep).
"""
import importlib.util
import pathlib

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    _here = pathlib.Path(__file__).parent
    # only unconditional (column-0) imports make a module uncollectable;
    # modules that guard the import (e.g. tests/test_witness.py) still run
    collect_ignore = sorted(
        p.name for p in _here.glob("test_*.py")
        if any(line.startswith(("from hypothesis import",
                                "import hypothesis"))
               for line in p.read_text(encoding="utf-8").splitlines())
    )
