"""Reproduces the paper's Fig. 1 worked example exactly.

Parameters: n=5, k=2, d=4, M=480 Mb, alpha = M/k = 240 Mb,
beta = alpha/(d-k+1) = 80 Mb.  Direct capacities (Mbps):
v1->v0 = 70, v2->v0 = 50, v3->v0 = 20, v4->v0 = 10; inter-provider link
v4->v1 = 35 (the one the tree uses); all other inter-provider links low
(5 Mbps, the bottom of the paper's 5-70 Mbps range).

Expected regeneration times (paper Section I text):
  STAR = 8 s, FR = 3 s, TR = 4 s, FTR = 2.67 s.
(The Fig. 1 caption transposes FR/TR; the per-scheme derivations in the
text give FR = 3 s and TR = 4 s, which is what we check.)
"""
import math

import pytest

from repro.core import (CodeParams, InfoFlowGraph, OverlayNetwork,
                        event_from_plan, fr_closed_form_msr, plan_fr,
                        plan_ftr, plan_rctree, plan_star, plan_tr)

P = CodeParams.msr(n=5, k=2, d=4, M=480.0)


def fig1_network() -> OverlayNetwork:
    net = OverlayNetwork.star_only([70.0, 50.0, 20.0, 10.0], cross=5.0)
    net.cap[4][1] = 35.0  # v4 -> v1
    return net


def test_params():
    assert P.alpha == 240.0
    assert P.beta == pytest.approx(80.0)


def test_star_8s():
    plan = plan_star(fig1_network(), P)
    plan.validate(fig1_network())
    assert plan.time == pytest.approx(8.0)
    assert plan.total_traffic == pytest.approx(4 * 80.0)


def test_fr_3s_closed_form():
    net = fig1_network()
    betas = fr_closed_form_msr(net.direct_caps(), P)
    # text: v1..v4 generate 150, 150, 60, 30
    assert betas == pytest.approx([150.0, 150.0, 60.0, 30.0])
    plan = plan_fr(net, P)
    plan.validate(net)
    assert plan.time == pytest.approx(3.0, rel=1e-6)


def test_tr_4s_and_tree_shape():
    net = fig1_network()
    plan = plan_tr(net, P)
    plan.validate(net)
    assert plan.time == pytest.approx(4.0, rel=1e-6)
    # Fig. 1(d): v4 relays through v1; v1, v2, v3 direct to newcomer
    assert plan.parent == {1: 0, 2: 0, 3: 0, 4: 1}
    # Theorem-3 flow on (v1, v0) is 2*beta
    assert plan.flows[(1, 0)] == pytest.approx(160.0)


def test_ftr_2_67s():
    net = fig1_network()
    plan = plan_ftr(net, P)
    plan.validate(net)
    assert plan.time == pytest.approx(8.0 / 3.0, rel=1e-4)
    # paper's beta = (133.33, 133.33, 53.33, 53.33); our LP reaches the same
    # optimal time with a cheaper vector (secondary traffic minimization), so
    # check the optimality structure instead of the particular vertex:
    from repro.core import sigma
    assert sigma(1, plan.betas, P.k, P.d) == pytest.approx(240.0, rel=1e-3)
    assert plan.parent == {1: 0, 2: 0, 3: 0, 4: 1}  # same tree as Fig. 1(e)
    # paper's vector is also feasible on this tree at the same time
    from repro.core import tree_flows
    paper_betas = [400 / 3, 400 / 3, 160 / 3, 160 / 3]
    fl = tree_flows(plan.parent, paper_betas, P.alpha)
    t_paper = max(fl[e] / net.c(*e) for e in fl)
    assert t_paper == pytest.approx(8.0 / 3.0, rel=1e-6)
    assert plan.total_traffic <= sum(fl.values()) + 1e-6


def test_scheme_ordering():
    """FTR <= min(FR, TR) <= STAR on this (and by design any) network."""
    net = fig1_network()
    t = {s.scheme: s.time for s in (plan_star(net, P), plan_fr(net, P),
                                    plan_tr(net, P), plan_ftr(net, P))}
    assert t["ftr"] <= t["fr"] + 1e-9
    assert t["ftr"] <= t["tr"] + 1e-9
    assert t["fr"] <= t["star"] + 1e-9
    assert t["tr"] <= t["star"] + 1e-9


def test_mds_preserved_by_all_four_schemes():
    """Single-repair min-cut check for star/fr/tr/ftr on the Fig. 1 network."""
    for planner in (plan_star, plan_fr, plan_tr, plan_ftr):
        net = fig1_network()
        plan = planner(net, P)
        g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
        # node 5 fails; nodes 1..4 are providers; newcomer gets id 6
        g.fail_and_repair(5, event_from_plan(plan, newcomer_id=6,
                                             provider_ids=[1, 2, 3, 4]))
        worst, flow = g.worst_collector()
        assert flow >= P.M - 1e-6, (planner.__name__, worst, flow)


def test_rctree_violates_mds_appendix_a():
    """Appendix A: RCTREE's min-cut through {v3, newcomer} is 2*beta + alpha
    = 400 Mb < M = 480 Mb."""
    net = fig1_network()
    plan = plan_rctree(net, P)
    g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
    g.fail_and_repair(5, event_from_plan(plan, newcomer_id=6,
                                         provider_ids=[1, 2, 3, 4]))
    worst, flow = g.worst_collector()
    assert flow < P.M - 1e-6, "RCTREE should break the MDS property"
    # the paper's specific counterexample value (tree has one relay edge)
    assert flow == pytest.approx(2 * 80.0 + 240.0)
