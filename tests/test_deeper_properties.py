"""Deeper system invariants (budget-extension coverage).

* FTR degenerates exactly to FR on star-only overlays (no useful
  inter-provider links) — the i=0 candidate of Algorithm 2;
* mixed-scheme multi-round repair histories keep MDS (rounds may use
  different planners — the real fleet case);
* executed tree plans with ceil-rounded integral flows keep MDS on the
  RLNC data plane;
* GF(2^16) linear algebra round-trips (the paper's Fig.-10 field).
"""
import math
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CodeParams, InfoFlowGraph, OverlayNetwork,
                        event_from_plan, plan_fr, plan_ftr, plan_star,
                        plan_tr, tree_flows)
from repro.coding import GF16, RLNC


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_ftr_equals_fr_on_star_only_networks(seed):
    rng = random.Random(seed)
    d = rng.randint(4, 8)
    k = rng.randint(2, d - 1)
    p = CodeParams.msr(n=d + 2, k=k, d=d, M=float(k * (d - k + 1) * 10))
    direct = [rng.uniform(10, 120) for _ in range(d)]
    net = OverlayNetwork.star_only(direct, cross=1e-6)
    fr = plan_fr(net, p)
    ftr = plan_ftr(net, p)
    assert ftr.time == pytest.approx(fr.time, rel=1e-4)
    assert all(pa == 0 for pa in ftr.parent.values())  # star tree chosen


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_mixed_scheme_multi_round_mds(seed):
    rng = random.Random(seed)
    k, d = 2, 4
    n = d + 2
    p = CodeParams.msr(n=n, k=k, d=d, M=float(k * (d - k + 1) * 5))
    g = InfoFlowGraph(p, initial_nodes=list(range(1, n + 1)))
    planners = [plan_star, plan_fr, plan_tr, plan_ftr]
    next_id = n + 1
    for r in range(4):
        failed = rng.choice(g.live)
        providers = rng.sample([x for x in g.live if x != failed], d)
        cap = [[rng.uniform(5, 120) if u != v else 0.0
                for v in range(d + 1)] for u in range(d + 1)]
        plan = planners[r % 4](OverlayNetwork(cap), p)
        g.fail_and_repair(failed, event_from_plan(plan, next_id, providers))
        next_id += 1
    worst, flow = g.worst_collector()
    assert flow >= p.M - 1e-6, (worst, flow)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_executed_tree_plan_with_ceil_rounding_keeps_mds(seed):
    """Integral executor semantics: ceil(beta_i), ceil(flows) on the RLNC
    data plane, tree relaying included, then every k-subset decodes."""
    from repro.coding import GF8
    rng = random.Random(seed)
    nprng = np.random.default_rng(seed)
    k, d, n = 2, 4, 6
    alpha = 6
    p = CodeParams(n=n, k=k, d=d, M=float(k * alpha), alpha=float(alpha))
    cap = [[rng.uniform(5, 120) if u != v else 0.0
            for v in range(d + 1)] for u in range(d + 1)]
    plan = plan_ftr(OverlayNetwork(cap), p)
    rl = RLNC(GF8)
    blocks = GF8.random((k * alpha, 8), nprng)
    nodes = dict(enumerate(rl.distribute(blocks, n, alpha, nprng), 1))
    providers = list(range(1, d + 1))
    children = {}
    for u, pa in plan.parent.items():
        children.setdefault(pa, []).append(u)

    def produce(u):
        own = rl.encode(nodes[u], math.ceil(plan.betas[u - 1] - 1e-9), nprng)
        recv = None
        for ch in children.get(u, []):
            part = produce(ch)
            recv = part if recv is None else recv.concat(part)
        if recv is None:
            return own
        quota = math.ceil(plan.flows[(u, plan.parent[u])] - 1e-9)
        return rl.relay(recv, own, quota, nprng)

    received = None
    for r in children.get(0, []):
        part = produce(r)
        received = part if received is None else received.concat(part)
    newcomer = rl.regenerate(received, alpha, nprng)
    survivors = {**{i: nodes[i] for i in range(1, n)}, n: newcomer}
    ids = sorted(survivors)
    ok = sum(rl.can_reconstruct([survivors[a], survivors[b]], k * alpha)
             for i, a in enumerate(ids) for b in ids[i + 1:])
    total = len(ids) * (len(ids) - 1) // 2
    assert ok >= total - 1  # whp over GF(2^8); allow one unlucky pair


def test_gf16_roundtrip():
    rng = np.random.default_rng(0)
    A = GF16.random((12, 12), rng)
    while GF16.rank(A) < 12:
        A = GF16.random((12, 12), rng)
    X = GF16.random((12, 5), rng)
    Y = GF16.matmul(A, X)
    np.testing.assert_array_equal(GF16.solve(A, Y), X)
    # field has full multiplicative order
    assert len(set(GF16.exp[:65535].tolist())) == 65535


def test_gf16_rlnc_distribute_reconstruct():
    rng = np.random.default_rng(1)
    rl = RLNC(GF16)
    blocks = GF16.random((8, 4), rng)
    nodes = rl.distribute(blocks, 5, 2, rng)
    got = rl.reconstruct(nodes[:4], 8)
    np.testing.assert_array_equal(got, blocks)
