"""Fleet simulator validated against closed forms (ISSUE 2 acceptance).

* no contention  -> fleet regeneration time == plan_time of the chosen plan;
* disjoint links -> coexisting repairs don't affect each other at all;
* shared bottleneck -> the fair-share model yields the analytic slowdown
  (2x while two plans overlap on one saturated link, including the
  staggered-start piecewise case);
* the flexible policy's mean backlog <= every fixed-scheme policy's on a
  seeded ~200-failure scenario;

plus degenerate-capacity coverage: near-zero links (the U1[0.3,120] tail),
exact ties across all links, and zero-capacity links — planners and the
link-sharing model must never divide by zero or emit negative times.
"""
import math

import numpy as np
import pytest

from repro.core import (BATCHED_SCHEMES, CodeParams, OverlayNetwork,
                        RepairPlan, SCHEMES, caps_tensor, plan_batch,
                        plan_time, plans_from_batch, tree_flows)
from repro.fleet import (FixedPolicy, FleetSimulator, FlexiblePolicy,
                         LinkShareModel, RepairPolicy, Scenario, simulate)
from repro.storage import uniform_matrix

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)
SCHEME_NAMES = ("star", "fr", "tr", "ftr")


def _fixed_caps(n: int, seed: int = 0, lo: float = 10.0, hi: float = 120.0):
    """A capacity model returning one deterministic matrix."""
    caps = np.random.default_rng(seed).uniform(lo, hi, size=(n, n))
    np.fill_diagonal(caps, 0.0)
    return caps, (lambda rng, m: caps.copy())


def _first_providers(failed, healthy, rng):
    return [h for h in healthy if h != failed][:PARAMS.d]


# ---------------------------------------------------------------------------
# 1. No contention: fleet time == plan_time of the chosen scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEME_NAMES)
def test_single_repair_matches_plan_time(scheme):
    n = 10
    caps, model = _fixed_caps(n, seed=3)
    sc = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                  failures=((10.0, 0),), capacity_model=model,
                  provider_picker=_first_providers)
    m = FleetSimulator(sc, FixedPolicy(scheme), PARAMS, seed=0).run()
    assert m.completed == 1 and m.aborted == 0
    ids = [0] + list(range(1, PARAMS.d + 1))
    overlay = OverlayNetwork(caps[np.ix_(ids, ids)].tolist())
    expect = SCHEMES[scheme](overlay, PARAMS).time
    assert m.regen_times[0] == pytest.approx(expect, rel=1e-9)
    # the vulnerability window adds the queue wait (zero here beyond start)
    assert m.vulnerability_windows[0] == pytest.approx(expect, rel=1e-9)


# ---------------------------------------------------------------------------
# 2. Disjoint links: coexistence changes nothing
# ---------------------------------------------------------------------------

def _group_picker(failed, healthy, rng):
    lo, hi = (1, 7) if failed == 0 else (8, 14)
    return [h for h in healthy if lo <= h < hi][:PARAMS.d]


def test_disjoint_repairs_are_independent():
    n = 14
    caps, model = _fixed_caps(n, seed=5)
    both = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                    failures=((10.0, 0), (10.0, 7)), capacity_model=model,
                    provider_picker=_group_picker)
    for scheme in ("star", "ftr"):
        mb = FleetSimulator(both, FixedPolicy(scheme), PARAMS, seed=0).run()
        assert mb.completed == 2
        solo_times = []
        for node in (0, 7):
            solo = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                            failures=((10.0, node),), capacity_model=model,
                            provider_picker=_group_picker)
            ms = FleetSimulator(solo, FixedPolicy(scheme), PARAMS,
                                seed=0).run()
            assert ms.completed == 1
            solo_times.append(ms.regen_times[0])
        # node 0's repair uses providers 1..6 only; node 7's uses 8..13:
        # no physical link is shared, so coexistence changes neither time
        np.testing.assert_allclose(sorted(mb.regen_times),
                                   sorted(solo_times), rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# 3. Shared saturated bottleneck: analytic fair-share slowdown
# ---------------------------------------------------------------------------

CRAFT_PARAMS = CodeParams(n=6, k=2, d=2, M=2.0, alpha=1.0)


class CraftedRelayPolicy(RepairPolicy):
    """Both providers relay through provider 2: tree 1 -> 2 -> newcomer.

    With the shared provider pair picked for every repair, the physical
    link (provider 1, provider 2) is common to all plans — the crafted
    probe for the fair-share model."""

    name = "crafted"

    def plan_batch(self, caps, params):
        plans = []
        for c in caps:
            parent = {1: 2, 2: 0}
            betas = [1.0, 1.0]
            flows = tree_flows(parent, betas, params.alpha)
            net = OverlayNetwork(c.tolist())
            plan = RepairPlan("crafted", params, parent, betas, flows, 0.0)
            plan.time = plan_time(plan, net)
            plans.append(plan)
        return plans


def _bottleneck_model(n=6, c_slow=10.0, c_fast=1e6):
    caps = np.full((n, n), c_fast)
    np.fill_diagonal(caps, 0.0)
    caps[4, 5] = c_slow                  # the saturated link
    return caps, (lambda rng, m: caps.copy())


def _shared_pair_picker(failed, healthy, rng):
    return [4, 5]


def test_shared_bottleneck_fair_share_slowdown():
    _, model = _bottleneck_model()
    base = dict(num_nodes=6, duration=100.0, failure_rate=0.0,
                capacity_model=model, provider_picker=_shared_pair_picker)
    # solo: flow 1 over the c=10 link -> 0.1 s
    ms = FleetSimulator(Scenario(failures=((0.0, 0),), **base),
                        CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert ms.regen_times == [pytest.approx(0.1, abs=1e-12)]
    # full overlap: both plans share the link the whole time -> exactly 2x
    m2 = FleetSimulator(Scenario(failures=((0.0, 0), (0.0, 1)), **base),
                        CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m2.completed == 2
    np.testing.assert_allclose(m2.regen_times, [0.2, 0.2], rtol=0,
                               atol=1e-12)
    # staggered: A alone for 0.05 s (half done), then shares until finishing
    # at 0.15; B ran 0.1 s at half rate + 0.05 s at full rate -> also 0.15
    mst = FleetSimulator(Scenario(failures=((0.0, 0), (0.05, 1)), **base),
                         CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert mst.completed == 2
    np.testing.assert_allclose(sorted(mst.regen_times), [0.15, 0.15],
                               rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# 4. Flexible policy dominates fixed schemes on backlog
# ---------------------------------------------------------------------------

def test_flexible_backlog_dominates_fixed():
    """Seeded ~200-failure scenario over the paper's widest heterogeneity
    (U[0.3, 120]): picking the fastest scheme per repair must not queue
    more work than any fixed scheme."""
    params = CodeParams.msr(n=12, k=3, d=6, M=600.0)
    sc = Scenario(num_nodes=16, duration=7000.0, failure_rate=2e-3,
                  capacity_model=uniform_matrix(0.3, 120.0))
    flex = FleetSimulator(sc, FlexiblePolicy(), params, seed=42).run()
    assert flex.completed + flex.aborted >= 150   # ~200 failure events
    flex_backlog = flex.summary()["mean_backlog"]
    assert math.isfinite(flex_backlog)
    for scheme in SCHEME_NAMES:
        fixed = FleetSimulator(sc, FixedPolicy(scheme), params, seed=42).run()
        assert flex_backlog <= fixed.summary()["mean_backlog"] + 1e-9, scheme


# ---------------------------------------------------------------------------
# Degenerate capacities: planners (scalar + batched)
# ---------------------------------------------------------------------------

def _tail_nets(count=6, d=6, seed=9):
    """U1[0.3,120]-tail overlays: a large share of links pinned at the 0.3
    floor, the rest fast — the regime where naive division blows up."""
    rng = np.random.default_rng(seed)
    nets = []
    for _ in range(count):
        cap = rng.uniform(0.3, 120.0, size=(d + 1, d + 1))
        slow = rng.random(size=cap.shape) < 0.4
        cap[slow] = 0.3
        np.fill_diagonal(cap, 0.0)
        nets.append(OverlayNetwork(cap.tolist()))
    return nets


def test_planners_near_zero_capacity_tail():
    nets = _tail_nets()
    caps = caps_tensor(nets)
    for s in SCHEME_NAMES:
        res = BATCHED_SCHEMES[s](caps, PARAMS)
        assert np.isfinite(res.times).all() and (res.times >= 0).all(), s
        assert (res.betas >= -1e-12).all(), s
        for net, plan in zip(nets, plans_from_batch(res, PARAMS)):
            assert plan.time >= 0 and math.isfinite(plan.time)
            plan.validate(net)
        scalar = [SCHEMES[s](net, PARAMS) for net in nets]
        np.testing.assert_allclose(res.times, [p.time for p in scalar],
                                   rtol=1e-9, atol=1e-6, err_msg=s)


def test_planners_all_links_tied():
    d = PARAMS.d
    cap = np.full((d + 1, d + 1), 50.0)
    np.fill_diagonal(cap, 0.0)
    net = OverlayNetwork(cap.tolist())
    caps = caps_tensor([net])
    for s in SCHEME_NAMES:
        scalar = SCHEMES[s](net, PARAMS)
        assert math.isfinite(scalar.time) and scalar.time >= 0, s
        res = BATCHED_SCHEMES[s](caps, PARAMS)
        assert res.times[0] == pytest.approx(scalar.time, rel=1e-9, abs=1e-9)


# ---------------------------------------------------------------------------
# Degenerate capacities: the link-sharing model
# ---------------------------------------------------------------------------

def test_share_model_saturated_and_zero_links():
    caps = np.array([[0.0, 10.0, 0.0],
                     [10.0, 0.0, 4.0],
                     [0.0, 4.0, 0.0]])
    with np.errstate(divide="raise", invalid="raise"):
        m = LinkShareModel(caps)
        links = [((1, 2), 8.0)]
        m.acquire(links)
        assert m.share((1, 2)) == pytest.approx(4.0)
        m.acquire(links)                      # second plan on the same link
        assert m.share((1, 2)) == pytest.approx(2.0)
        assert m.residual((1, 2)) == pytest.approx(4.0 / 3.0)
        # saturated link shared by two plans: each needs 8 blocks at 2 b/s
        assert m.nominal_time(links) == pytest.approx(4.0)
        # a zero-capacity link stalls (inf), it must not raise
        assert m.nominal_time([((0, 2), 1.0)]) == math.inf
        assert m.residual((0, 2)) == 0.0
        # negligible flows occupy nothing and contribute no time
        assert m.nominal_time([((1, 2), 0.0)]) == 0.0
        m.release(links)
        m.release(links)
        assert m.users == {}
        overlay = m.residual_overlay([0, 1, 2])
        assert np.isfinite(overlay).all() and (overlay >= 0).all()


def test_fleet_survives_near_zero_and_tied_capacities():
    """End-to-end: the simulator on U[0.3,120]-tail and all-tied clusters
    stays finite, monotone, and non-negative."""
    params = CodeParams.msr(n=8, k=2, d=4, M=100.0)

    def tied(rng, n):
        cap = np.full((n, n), 7.0)
        np.fill_diagonal(cap, 0.0)
        return cap

    for model in (uniform_matrix(0.3, 120.0), tied):
        sc = Scenario(num_nodes=10, duration=800.0, failure_rate=3e-3,
                      capacity_model=model)
        s = simulate(sc, FlexiblePolicy(), params, seed=1)
        assert math.isfinite(s["mean_backlog"]) and s["mean_backlog"] >= 0
        assert s["regen_p99"] >= s["regen_p50"] >= 0
        assert s["completed"] >= 1


# ---------------------------------------------------------------------------
# Batched <-> scalar plan materialization used by the policies
# ---------------------------------------------------------------------------

def test_plans_from_batch_validate():
    rng = np.random.default_rng(11)
    nets = []
    for _ in range(5):
        cap = rng.uniform(10.0, 120.0, size=(PARAMS.d + 1, PARAMS.d + 1))
        np.fill_diagonal(cap, 0.0)
        nets.append(OverlayNetwork(cap.tolist()))
    caps = caps_tensor(nets)
    for s in SCHEME_NAMES:
        plans = plans_from_batch(plan_batch(caps, PARAMS, s), PARAMS)
        for net, plan in zip(nets, plans):
            plan.validate(net)
            assert plan.scheme == s
