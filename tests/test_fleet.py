"""Fleet simulator validated against closed forms (ISSUE 2 acceptance).

* no contention  -> fleet regeneration time == plan_time of the chosen plan;
* disjoint links -> coexisting repairs don't affect each other at all;
* shared bottleneck -> the fair-share model yields the analytic slowdown
  (2x while two plans overlap on one saturated link, including the
  staggered-start piecewise case);
* the flexible policy's mean backlog <= every fixed-scheme policy's on a
  seeded ~200-failure scenario;

plus degenerate-capacity coverage: near-zero links (the U1[0.3,120] tail),
exact ties across all links, and zero-capacity links — planners and the
link-sharing model must never divide by zero or emit negative times.

Repair-lifecycle coverage (ISSUE 3): closed forms for partial-progress
carryover (a repair that loses a provider resumes from its banked blocks)
and in-flight plan migration (a capacity shock triggers a credited
re-plan); the progress-vector conservation property (banked + remaining
edge work == plan total across arbitrary abort/migration sequences); the
four fleet-loop bug regressions (redundant-injection rng stability,
phantom-read teardown on endpoint failure, MTTDL integration past the
loss boundary, zero-capacity plan deferral); and a bitwise golden guard
pinning the migration-off quick-bench rows to the pre-lifecycle values.
"""
import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro.core import (CodeParams, OverlayNetwork, RepairPlan,
                        caps_tensor, get_scheme, plan_many, plan_time,
                        plans_from_batch, tree_flows)
from repro.fleet import (Event, FixedPolicy, FleetMetrics, FleetSimulator,
                         FlexiblePolicy, LinkShareModel, RepairPolicy,
                         Scenario, apply_credit, capacity_weather,
                         flaky_providers, make_policy, simulate)
from repro.fleet.events import READ_DEPARTURE
from repro.storage import uniform_matrix

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)
SCHEME_NAMES = ("star", "fr", "tr", "ftr")


def _fixed_caps(n: int, seed: int = 0, lo: float = 10.0, hi: float = 120.0):
    """A capacity model returning one deterministic matrix."""
    caps = np.random.default_rng(seed).uniform(lo, hi, size=(n, n))
    np.fill_diagonal(caps, 0.0)
    return caps, (lambda rng, m: caps.copy())


def _first_providers(failed, healthy, rng):
    return [h for h in healthy if h != failed][:PARAMS.d]


# ---------------------------------------------------------------------------
# 1. No contention: fleet time == plan_time of the chosen scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEME_NAMES)
def test_single_repair_matches_plan_time(scheme):
    n = 10
    caps, model = _fixed_caps(n, seed=3)
    sc = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                  failures=((10.0, 0),), capacity_model=model,
                  provider_picker=_first_providers)
    m = FleetSimulator(sc, FixedPolicy(scheme), PARAMS, seed=0).run()
    assert m.completed == 1 and m.aborted == 0
    ids = [0] + list(range(1, PARAMS.d + 1))
    overlay = OverlayNetwork(caps[np.ix_(ids, ids)].tolist())
    expect = get_scheme(scheme).scalar(overlay, PARAMS).time
    assert m.regen_times[0] == pytest.approx(expect, rel=1e-9)
    # the vulnerability window adds the queue wait (zero here beyond start)
    assert m.vulnerability_windows[0] == pytest.approx(expect, rel=1e-9)


# ---------------------------------------------------------------------------
# 2. Disjoint links: coexistence changes nothing
# ---------------------------------------------------------------------------

def _group_picker(failed, healthy, rng):
    lo, hi = (1, 7) if failed == 0 else (8, 14)
    return [h for h in healthy if lo <= h < hi][:PARAMS.d]


def test_disjoint_repairs_are_independent():
    n = 14
    caps, model = _fixed_caps(n, seed=5)
    both = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                    failures=((10.0, 0), (10.0, 7)), capacity_model=model,
                    provider_picker=_group_picker)
    for scheme in ("star", "ftr"):
        mb = FleetSimulator(both, FixedPolicy(scheme), PARAMS, seed=0).run()
        assert mb.completed == 2
        solo_times = []
        for node in (0, 7):
            solo = Scenario(num_nodes=n, duration=1000.0, failure_rate=0.0,
                            failures=((10.0, node),), capacity_model=model,
                            provider_picker=_group_picker)
            ms = FleetSimulator(solo, FixedPolicy(scheme), PARAMS,
                                seed=0).run()
            assert ms.completed == 1
            solo_times.append(ms.regen_times[0])
        # node 0's repair uses providers 1..6 only; node 7's uses 8..13:
        # no physical link is shared, so coexistence changes neither time
        np.testing.assert_allclose(sorted(mb.regen_times),
                                   sorted(solo_times), rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# 3. Shared saturated bottleneck: analytic fair-share slowdown
# ---------------------------------------------------------------------------

CRAFT_PARAMS = CodeParams(n=6, k=2, d=2, M=2.0, alpha=1.0)


class CraftedRelayPolicy(RepairPolicy):
    """Both providers relay through provider 2: tree 1 -> 2 -> newcomer.

    With the shared provider pair picked for every repair, the physical
    link (provider 1, provider 2) is common to all plans — the crafted
    probe for the fair-share model."""

    name = "crafted"

    def plan_batch(self, caps, params):
        plans = []
        for c in caps:
            parent = {1: 2, 2: 0}
            betas = [1.0, 1.0]
            flows = tree_flows(parent, betas, params.alpha)
            net = OverlayNetwork(c.tolist())
            plan = RepairPlan("crafted", params, parent, betas, flows, 0.0)
            plan.time = plan_time(plan, net)
            plans.append(plan)
        return plans


def _bottleneck_model(n=6, c_slow=10.0, c_fast=1e6):
    caps = np.full((n, n), c_fast)
    np.fill_diagonal(caps, 0.0)
    caps[4, 5] = c_slow                  # the saturated link
    return caps, (lambda rng, m: caps.copy())


def _shared_pair_picker(failed, healthy, rng):
    return [4, 5]


def test_shared_bottleneck_fair_share_slowdown():
    _, model = _bottleneck_model()
    base = dict(num_nodes=6, duration=100.0, failure_rate=0.0,
                capacity_model=model, provider_picker=_shared_pair_picker)
    # solo: flow 1 over the c=10 link -> 0.1 s
    ms = FleetSimulator(Scenario(failures=((0.0, 0),), **base),
                        CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert ms.regen_times == [pytest.approx(0.1, abs=1e-12)]
    # full overlap: both plans share the link the whole time -> exactly 2x
    m2 = FleetSimulator(Scenario(failures=((0.0, 0), (0.0, 1)), **base),
                        CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m2.completed == 2
    np.testing.assert_allclose(m2.regen_times, [0.2, 0.2], rtol=0,
                               atol=1e-12)
    # staggered: A alone for 0.05 s (half done), then shares until finishing
    # at 0.15; B ran 0.1 s at half rate + 0.05 s at full rate -> also 0.15
    mst = FleetSimulator(Scenario(failures=((0.0, 0), (0.05, 1)), **base),
                         CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    assert mst.completed == 2
    np.testing.assert_allclose(sorted(mst.regen_times), [0.15, 0.15],
                               rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# 4. Flexible policy dominates fixed schemes on backlog
# ---------------------------------------------------------------------------

def test_flexible_backlog_dominates_fixed():
    """Seeded ~200-failure scenario over the paper's widest heterogeneity
    (U[0.3, 120]): picking the fastest scheme per repair must not queue
    more work than any fixed scheme."""
    params = CodeParams.msr(n=12, k=3, d=6, M=600.0)
    sc = Scenario(num_nodes=16, duration=7000.0, failure_rate=2e-3,
                  capacity_model=uniform_matrix(0.3, 120.0))
    flex = FleetSimulator(sc, FlexiblePolicy(), params, seed=42).run()
    assert flex.completed + flex.aborted >= 150   # ~200 failure events
    flex_backlog = flex.summary()["mean_backlog"]
    assert math.isfinite(flex_backlog)
    for scheme in SCHEME_NAMES:
        fixed = FleetSimulator(sc, FixedPolicy(scheme), params, seed=42).run()
        assert flex_backlog <= fixed.summary()["mean_backlog"] + 1e-9, scheme


# ---------------------------------------------------------------------------
# Degenerate capacities: planners (scalar + batched)
# ---------------------------------------------------------------------------

def _tail_nets(count=6, d=6, seed=9):
    """U1[0.3,120]-tail overlays: a large share of links pinned at the 0.3
    floor, the rest fast — the regime where naive division blows up."""
    rng = np.random.default_rng(seed)
    nets = []
    for _ in range(count):
        cap = rng.uniform(0.3, 120.0, size=(d + 1, d + 1))
        slow = rng.random(size=cap.shape) < 0.4
        cap[slow] = 0.3
        np.fill_diagonal(cap, 0.0)
        nets.append(OverlayNetwork(cap.tolist()))
    return nets


def test_planners_near_zero_capacity_tail():
    nets = _tail_nets()
    caps = caps_tensor(nets)
    for s in SCHEME_NAMES:
        res = get_scheme(s).batched(caps, PARAMS)
        assert np.isfinite(res.times).all() and (res.times >= 0).all(), s
        assert (res.betas >= -1e-12).all(), s
        for net, plan in zip(nets, plans_from_batch(res, PARAMS)):
            assert plan.time >= 0 and math.isfinite(plan.time)
            plan.validate(net)
        scalar = [get_scheme(s).scalar(net, PARAMS) for net in nets]
        np.testing.assert_allclose(res.times, [p.time for p in scalar],
                                   rtol=1e-9, atol=1e-6, err_msg=s)


def test_planners_all_links_tied():
    d = PARAMS.d
    cap = np.full((d + 1, d + 1), 50.0)
    np.fill_diagonal(cap, 0.0)
    net = OverlayNetwork(cap.tolist())
    caps = caps_tensor([net])
    for s in SCHEME_NAMES:
        scalar = get_scheme(s).scalar(net, PARAMS)
        assert math.isfinite(scalar.time) and scalar.time >= 0, s
        res = get_scheme(s).batched(caps, PARAMS)
        assert res.times[0] == pytest.approx(scalar.time, rel=1e-9, abs=1e-9)


# ---------------------------------------------------------------------------
# Degenerate capacities: the link-sharing model
# ---------------------------------------------------------------------------

def test_share_model_saturated_and_zero_links():
    caps = np.array([[0.0, 10.0, 0.0],
                     [10.0, 0.0, 4.0],
                     [0.0, 4.0, 0.0]])
    with np.errstate(divide="raise", invalid="raise"):
        m = LinkShareModel(caps)
        links = [((1, 2), 8.0)]
        m.acquire(links)
        assert m.share((1, 2)) == pytest.approx(4.0)
        m.acquire(links)                      # second plan on the same link
        assert m.share((1, 2)) == pytest.approx(2.0)
        assert m.residual((1, 2)) == pytest.approx(4.0 / 3.0)
        # saturated link shared by two plans: each needs 8 blocks at 2 b/s
        assert m.nominal_time(links) == pytest.approx(4.0)
        # a zero-capacity link stalls (inf), it must not raise
        assert m.nominal_time([((0, 2), 1.0)]) == math.inf
        assert m.residual((0, 2)) == 0.0
        # negligible flows occupy nothing and contribute no time
        assert m.nominal_time([((1, 2), 0.0)]) == 0.0
        m.release(links)
        m.release(links)
        assert m.users == {}
        overlay = m.residual_overlay([0, 1, 2])
        assert np.isfinite(overlay).all() and (overlay >= 0).all()


def test_fleet_survives_near_zero_and_tied_capacities():
    """End-to-end: the simulator on U[0.3,120]-tail and all-tied clusters
    stays finite, monotone, and non-negative."""
    params = CodeParams.msr(n=8, k=2, d=4, M=100.0)

    def tied(rng, n):
        cap = np.full((n, n), 7.0)
        np.fill_diagonal(cap, 0.0)
        return cap

    for model in (uniform_matrix(0.3, 120.0), tied):
        sc = Scenario(num_nodes=10, duration=800.0, failure_rate=3e-3,
                      capacity_model=model)
        s = simulate(sc, FlexiblePolicy(), params, seed=1)
        assert math.isfinite(s["mean_backlog"]) and s["mean_backlog"] >= 0
        assert s["regen_p99"] >= s["regen_p50"] >= 0
        assert s["completed"] >= 1


# ---------------------------------------------------------------------------
# Batched <-> scalar plan materialization used by the policies
# ---------------------------------------------------------------------------

def test_plans_from_batch_validate():
    rng = np.random.default_rng(11)
    nets = []
    for _ in range(5):
        cap = rng.uniform(10.0, 120.0, size=(PARAMS.d + 1, PARAMS.d + 1))
        np.fill_diagonal(cap, 0.0)
        nets.append(OverlayNetwork(cap.tolist()))
    caps = caps_tensor(nets)
    for s in SCHEME_NAMES:
        plans = plans_from_batch(plan_many(caps, PARAMS, s), PARAMS)
        for net, plan in zip(nets, plans):
            plan.validate(net)
            assert plan.scheme == s


# ---------------------------------------------------------------------------
# Partial-progress carryover: closed forms
# ---------------------------------------------------------------------------

def _relay_bottleneck_model(n=6, c_slow=10.0, c_fast=1e6):
    """Every link fast except the provider->newcomer edge (5, 0): whichever
    relay tree the crafted policy builds, (5, 0) is the bottleneck."""
    caps = np.full((n, n), c_fast)
    np.fill_diagonal(caps, 0.0)
    caps[5, 0] = c_slow
    return caps, (lambda rng, m: caps.copy())


def _failover_picker(failed, healthy, rng):
    return [4, 5] if 4 in healthy else [3, 5]


@pytest.mark.parametrize("carryover,expect_vuln", [(True, 0.10),
                                                   (False, 0.15)])
def test_carryover_resumes_from_banked_blocks(carryover, expect_vuln):
    """Relay 4 -> 5 -> 0 with the (5, 0) link as the c=10 bottleneck: the
    solo plan takes 0.1 s.  Provider 4 dies at t=0.05 with the repair half
    done — 0.5 blocks are already banked on (5, 0).  With carryover the
    re-plan (3 -> 5 -> 0, same bottleneck) owes only the missing 0.5
    blocks and finishes at 0.10; a cold restart re-sends everything and
    finishes at 0.15."""
    _, model = _relay_bottleneck_model()
    sc = Scenario(num_nodes=6, duration=10.0, failure_rate=0.0,
                  failures=((0.0, 0), (0.05, 4)), capacity_model=model,
                  provider_picker=_failover_picker, carryover=carryover)
    m = FleetSimulator(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0).run()
    # slot 0 plus the failed provider 4 (whose own repair over fast links
    # is near-instant) both regenerate; slot 0's window is the long one
    assert m.completed == 2 and m.aborted == 1
    assert max(m.vulnerability_windows) == pytest.approx(expect_vuln,
                                                         abs=1e-12)
    if carryover:
        assert m.carryover_aborts == 1 and m.cold_aborts == 0
        # 0.5 banked blocks credited against the 2.0-block re-plan
        assert m.work_saved == pytest.approx(0.5, abs=1e-12)
        assert m.credit_fractions == [pytest.approx(0.25, abs=1e-12)]
    else:
        assert m.carryover_aborts == 0 and m.cold_aborts == 1
        assert m.work_saved == 0.0


def test_apply_credit_accounting():
    flows = [((1, 0), 4.0), ((2, 1), 2.0)]
    bank = {(1, 0): 1.5, (2, 1): 5.0, (9, 0): 7.0}
    links, credited, total = apply_credit(flows, bank)
    assert links == [((1, 0), 2.5)]     # (2, 1) fully prepaid drops out
    assert credited == pytest.approx(3.5) and total == pytest.approx(6.0)
    assert bank[(9, 0)] == 7.0          # unused entries stay banked


# ---------------------------------------------------------------------------
# In-flight plan migration: closed form at a crafted capacity shock
# ---------------------------------------------------------------------------

class CraftedBestOfPolicy(RepairPolicy):
    """Pick the faster of {relay 1 -> 2 -> 0, star} under the given caps —
    a two-point flexible policy with closed-form times."""

    name = "crafted_best"

    def plan_batch(self, caps, params):
        plans = []
        for c in caps:
            net = OverlayNetwork(c.tolist())
            cands = []
            for parent in ({1: 2, 2: 0}, {1: 0, 2: 0}):
                betas = [1.0, 1.0]
                flows = tree_flows(parent, betas, params.alpha)
                p = RepairPlan("crafted", params, parent, betas, flows, 0.0)
                p.time = plan_time(p, net)
                cands.append(p)
            plans.append(min(cands, key=lambda p: p.time))
        return plans


class _OneShockSim(FleetSimulator):
    """Deterministic shock: at the first CAPACITY_SHOCK event the relay
    link (4, 5) collapses and the direct link (4, 0) opens up."""

    def _capacity_shock(self):
        self.cluster.caps[4, 5] = 0.01
        self.cluster.caps[4, 0] = 100.0
        self._replan_pending = True


@pytest.mark.parametrize("migration,expect_vuln", [(True, 0.015),
                                                   (False, 50.005)])
def test_migration_escapes_gutted_bottleneck(migration, expect_vuln):
    """Relay 4 -> 5 -> 0 is the fast plan (0.01 s) until the shock at
    t=0.005 guts (4, 5) to 0.01 b/s; the repair is half done.  With
    migration it re-plans to the now-open star, credits the 0.5 blocks
    already banked on (5, 0), and finishes 0.01 s after the shock; frozen
    plans crawl the remaining 0.5 blocks at 0.01 b/s for 50 s."""
    n = 6
    caps = np.full((n, n), 100.0)
    np.fill_diagonal(caps, 0.0)
    caps[4, 0] = 0.1                    # direct path closed pre-shock
    model = (lambda rng, m: caps.copy())
    sc = Scenario(num_nodes=n, duration=100.0, failure_rate=0.0,
                  failures=((0.0, 0),), capacity_model=model,
                  provider_picker=_shared_pair_picker,
                  shock_period=0.005, migration=migration, carryover=True)
    m = _OneShockSim(sc, CraftedBestOfPolicy(), CRAFT_PARAMS, seed=0).run()
    assert m.completed == 1 and m.aborted == 0
    assert m.vulnerability_windows == [pytest.approx(expect_vuln,
                                                     rel=1e-12)]
    if migration:
        assert m.migrations == 1
        # 0.5 banked blocks credited against the 2.0-block star plan
        assert m.work_saved == pytest.approx(0.5, abs=1e-12)
        assert m.credit_fractions == [pytest.approx(0.25, abs=1e-12)]
    else:
        assert m.migrations == 0


# ---------------------------------------------------------------------------
# Conservation: banked + remaining edge work == plan total, always
# ---------------------------------------------------------------------------

class _ConservationSim(FleetSimulator):
    checks = 0

    def _advance(self, t):
        super()._advance(t)
        for r in self.active:
            for link, (banked, todo, total) in r.work_accounting().items():
                assert banked >= -1e-9 and todo >= -1e-9, (link, banked,
                                                           todo)
                assert abs(banked + todo - total) <= 1e-9 * max(1.0, total)
            type(self).checks += 1


def test_progress_vector_conservation_under_aborts_and_migrations():
    """Across seeded abort/carryover/migration sequences, every in-flight
    repair's banked-plus-outstanding work equals its current plan's edge
    totals at every event epoch — credit transfer neither creates nor
    destroys work."""
    params = CodeParams.msr(n=12, k=3, d=6, M=600.0)
    sc = dataclasses.replace(flaky_providers(12, duration=1200.0),
                             carryover=True, migration=True)
    aborted = migrations = 0
    for seed in (0, 1):
        m = _ConservationSim(sc, FlexiblePolicy(), params, seed=seed).run()
        aborted += m.aborted
        migrations += m.migrations
    assert _ConservationSim.checks > 200       # the invariant was exercised
    assert aborted > 0 and migrations > 0      # ... on the paths that matter


# ---------------------------------------------------------------------------
# Fleet-loop bug regressions (ISSUE 3 satellites)
# ---------------------------------------------------------------------------

def test_redundant_injected_failure_keeps_poisson_stream():
    """A FAILURE injection colliding with an already-down slot is a no-op
    and must not redraw the Poisson clock: two scenarios differing only in
    the redundant injection stay event-for-event identical."""
    n = 10
    caps = np.full((n, n), 10.0)
    np.fill_diagonal(caps, 0.0)
    model = (lambda rng, m: caps.copy())
    base = dict(num_nodes=n, duration=3000.0, failure_rate=1e-3,
                capacity_model=model)
    only = Scenario(failures=((5.0, 0),), **base)
    redundant = Scenario(failures=((5.0, 0), (6.0, 0)), **base)
    ma = simulate(only, FixedPolicy("star"), PARAMS, seed=7)
    mb = simulate(redundant, FixedPolicy("star"), PARAMS, seed=7)
    assert ma == mb


def test_failed_read_endpoint_releases_links():
    """Degraded reads whose source or destination fails are torn down with
    the node: their links must not linger as phantom flows.  A read into
    node 0 shares the repair's (5, 0) bottleneck — after node 0 fails, the
    repair must see the full solo share (0.1 s), not half of it."""
    _, model = _relay_bottleneck_model()
    sc = Scenario(num_nodes=6, duration=2000.0, failure_rate=0.0,
                  failures=((10.0, 0),), capacity_model=model,
                  provider_picker=_shared_pair_picker)
    sim = FleetSimulator(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0)
    into = [((5, 0), 1.0)]              # destination 0 fails
    outof = [((0, 3), 1.0)]             # source 0 fails
    for rid, links in ((101, into), (102, outof)):
        sim.shares.acquire(links)
        sim.reads[rid] = links
        sim.events.push(Event(1000.0, READ_DEPARTURE, (rid,)))
    m = sim.run()
    assert m.completed == 1
    assert m.regen_times[0] == pytest.approx(0.1, abs=1e-12)
    assert sim.reads == {} and sim.shares.users == {}   # stale departures
    #                                                     were no-ops


def test_mttdl_integrates_past_loss_boundary():
    """expected_losses accrues the conditional ruin intensity for every
    state at or past unavailable == n - k, not only at equality."""
    m = FleetMetrics(n=6, k=2, failure_rate=0.1)
    m.observe(0.0, 0, 4)                # at the boundary (n - k = 4)
    m.observe(2.0, 0, 5)                # past it: one node left
    m.observe(5.0, 0, 0)
    assert m.at_risk_time == pytest.approx(2.0)
    # [0, 2): rate 0.1 * 2 healthy; [2, 5): rate 0.1 * 1 healthy
    assert m.expected_losses == pytest.approx(0.1 * 2 * 2 + 0.1 * 1 * 3)
    assert m.summary()["mttdl_estimate"] == pytest.approx(5.0 / 0.7)


def _zero_link_picker(failed, healthy, rng):
    return [4, 5] if failed == 0 else [2, 3]


def test_zero_capacity_plan_defers_instead_of_wedging():
    """A repair planned onto a zero-capacity link (infinite plan time)
    must not start: under static capacities it would hold its links and a
    max_concurrent slot forever.  It is requeued, and — crucially — its
    deferral frees the admission slot within the same epoch, so the next
    queued repair still starts."""
    n = 6
    caps = np.full((n, n), 100.0)
    np.fill_diagonal(caps, 0.0)
    caps[5, 0] = 0.0                    # slot 0's plans all route over this
    model = (lambda rng, m: caps.copy())
    sc = Scenario(num_nodes=n, duration=5.0, failure_rate=0.0,
                  failures=((0.0, 0), (0.0, 1)), capacity_model=model,
                  provider_picker=_zero_link_picker, max_concurrent=1)
    sim = FleetSimulator(sc, CraftedRelayPolicy(), CRAFT_PARAMS, seed=0)
    m = sim.run()
    assert m.completed == 1             # node 1 was not starved
    assert m.regen_times == [pytest.approx(0.01, abs=1e-12)]
    assert sim.active == []             # the dead repair never started ...
    assert [q.node for q in sim.queue] == [0]   # ... and is still queued
    assert sim.shares.users == {}       # holding no links
    assert math.isfinite(m.summary()["mean_backlog"])


# ---------------------------------------------------------------------------
# Lifecycle acceptance: migration + carryover tighten the stress scenarios
# ---------------------------------------------------------------------------

def test_lifecycle_tightens_flaky_and_weather():
    """On the abort-heavy flaky_providers scenario and a storm-grade
    capacity_weather (fast deep shocks over slow links), turning on
    carryover + migration must not worsen mean backlog or the p99
    vulnerability window for the flexible policy."""
    cases = [
        ("flaky_providers", flaky_providers(16), 0),
        ("capacity_weather",
         capacity_weather(16, failure_rate=3e-3, duration=2500.0,
                          shock_period=10.0, shock_lo=0.02,
                          cap_lo=1.0, cap_hi=30.0), 3),
    ]
    for name, sc, seed in cases:
        base = simulate(sc, FlexiblePolicy(), PARAMS, seed=seed)
        on = simulate(dataclasses.replace(sc, carryover=True,
                                          migration=True),
                      FlexiblePolicy(), PARAMS, seed=seed)
        assert on["mean_backlog"] <= base["mean_backlog"], name
        assert on["vulnerability_p99"] <= base["vulnerability_p99"], name
        assert on["migrations"] > 0 and on["carryover_aborts"] > 0, name
        assert on["cold_aborts"] == 0, name
        assert on["aborted"] == on["carryover_aborts"], name
        assert 0.0 < on["work_saved_fraction"] <= 1.0, name
        assert base["migrations"] == 0 and base["work_saved_blocks"] == 0.0


# ---------------------------------------------------------------------------
# Bitwise guard: the migration-off default path reproduces the golden rows
# ---------------------------------------------------------------------------

def test_default_path_matches_golden_quick_rows():
    """With carryover and migration off, the quick-bench configurations
    reproduce benchmarks/golden/fleet_quick_seed0.json exactly — every
    summary value bitwise equal.  The legacy rows in that file are pinned
    to their pre-lifecycle (PR 2) values."""
    import benchmarks.fleet_scale as fs

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "benchmarks", "golden",
                           "fleet_quick_seed0.json")) as f:
        golden = json.load(f)
    sweep = {name: (sc, pol) for name, sc, pol in fs._sweep(quick=True)}
    assert set(golden["configs"]) <= set(sweep)
    for name, expect in golden["configs"].items():
        sc, pol = sweep[name]
        assert not (sc.carryover or sc.migration), name
        # the robustness layer (ISSUE 6) must be fully inert on these rows
        assert sc.estimate_noise == 0.0, name
        assert sc.estimate_refresh_period == 0.0, name
        assert sc.degrade_rate == 0.0 and sc.degradations == (), name
        assert sc.watchdog_period == 0.0 and not sc.degraded_d, name
        got = simulate(sc, make_policy(pol), fs._params(),
                       seed=fs._config_seed(golden["root_seed"], name))
        # the golden is strict JSON since schema v2: non-finite floats
        # (quiet rows' mttdl_estimate) are stored as null
        from repro.obs import json_sanitize
        assert json_sanitize(got) == expect, name
