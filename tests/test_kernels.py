"""Pallas GF(2^8) matmul kernel vs the pure-jnp ref and the table oracle.

Sweeps shapes (including non-block-multiples via the padding wrapper) and
block sizes; property tests over random matrices.  interpret=True executes
the kernel body on CPU (this container's only backend); the BlockSpecs are
the TPU deployment configuration.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.coding.gf import GF8
from repro.kernels.gf_matmul import gf_matmul_pallas
from repro.kernels.ops import gf_matmul, gf_matmul_reference

RNG = np.random.default_rng(1234)


def _rand(m, k, n):
    return (RNG.integers(0, 256, (m, k), dtype=np.uint8),
            RNG.integers(0, 256, (k, n), dtype=np.uint8))


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (3, 5, 2), (17, 33, 9), (128, 512, 128),
    (130, 700, 257), (256, 512, 384), (64, 1024, 64),
])
def test_matches_table_oracle(m, k, n):
    a, b = _rand(m, k, n)
    want = GF8.matmul(a, b)
    np.testing.assert_array_equal(np.asarray(gf_matmul(a, b)), want)
    np.testing.assert_array_equal(np.asarray(gf_matmul_reference(a, b)), want)


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 512), (128, 128, 128),
                                      (256, 128, 256), (128, 256, 128)])
def test_block_shape_sweep(bm, bn, bk):
    """The kernel result must be block-size invariant (same math, different
    VMEM tiling)."""
    a, b = _rand(2 * bm, 2 * bk, 2 * bn)
    want = GF8.matmul(a, b)
    got = gf_matmul_pallas(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn,
                           bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96), st.integers(1, 64),
       st.integers(0, 2**31 - 1))
def test_property_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, k), dtype=np.uint8)
    b = rng.integers(0, 256, (k, n), dtype=np.uint8)
    want = GF8.matmul(a, b)
    np.testing.assert_array_equal(np.asarray(gf_matmul(a, b)), want)


def test_linearity_and_identity():
    """Kernel respects GF structure: A@(B^C) == (A@B)^(A@C); A@I == A."""
    a, b = _rand(32, 48, 24)
    c = RNG.integers(0, 256, b.shape, dtype=np.uint8)
    left = np.asarray(gf_matmul(a, b ^ c))
    right = np.asarray(gf_matmul(a, b)) ^ np.asarray(gf_matmul(a, c))
    np.testing.assert_array_equal(left, right)
    eye = np.eye(48, dtype=np.uint8)
    np.testing.assert_array_equal(np.asarray(gf_matmul(a, eye)), a)


def test_zero_padding_soundness():
    """Padding with zeros must not perturb the visible result region."""
    a, b = _rand(100, 200, 50)
    np.testing.assert_array_equal(np.asarray(gf_matmul(a, b)), GF8.matmul(a, b))
