"""Beyond-paper extensions: transfer-model robustness + multi-failure."""
import random

import pytest

from repro.core import (CodeParams, OverlayNetwork, plan_fr, plan_ftr,
                        plan_multi_failures, plan_star, plan_tr,
                        store_and_forward_time, streaming_time_with_latency)


def fig1_net():
    net = OverlayNetwork.star_only([70.0, 50.0, 20.0, 10.0], cross=5.0)
    net.cap[4][1] = 35.0
    return net


P = CodeParams.msr(n=5, k=2, d=4, M=480.0)


def test_star_unaffected_by_store_and_forward():
    plan = plan_star(fig1_net(), P)
    assert store_and_forward_time(plan, fig1_net()) == pytest.approx(plan.time)


def test_tree_degrades_under_store_and_forward():
    """TR's Fig. 1 tree: v4 relays through v1, so S&F serializes the hop."""
    net = fig1_net()
    plan = plan_tr(net, P)
    sf = store_and_forward_time(plan, net)
    assert sf > plan.time + 1e-9
    # v4 sends 80/35 = 2.286s, then v1 forwards 160/70 = 2.286s -> 4.571s
    assert sf == pytest.approx(80 / 35 + 160 / 70, rel=1e-6)


def test_streaming_latency_reduces_to_paper_model():
    net = fig1_net()
    plan = plan_ftr(net, P)
    assert streaming_time_with_latency(plan, net, 0.0) == pytest.approx(
        plan.time, rel=1e-6)
    assert streaming_time_with_latency(plan, net, 0.1) > plan.time


def test_sf_robustness_ordering():
    """Even under S&F, FTR should not be worse than STAR on random nets
    (trees only adopted when they pay)."""
    rng = random.Random(0)
    worse = 0
    for _ in range(10):
        d = 6
        cap = [[rng.uniform(10, 120) if u != v else 0.0
                for v in range(d + 1)] for u in range(d + 1)]
        net = OverlayNetwork(cap)
        p = CodeParams.msr(n=8, k=3, d=d, M=720.0)
        star = plan_star(net, p).time
        ftr = plan_ftr(net, p)
        if store_and_forward_time(ftr, net) > star + 1e-9:
            worse += 1
    # S&F can erase the tree advantage but rarely inverts it badly
    assert worse <= 3


def test_multi_failure_contention():
    rng = random.Random(1)
    d = 5
    p = CodeParams.msr(n=8, k=3, d=d, M=600.0)

    def rand_net():
        cap = [[rng.uniform(10, 120) if u != v else 0.0
                for v in range(d + 1)] for u in range(d + 1)]
        return OverlayNetwork(cap)

    overlays = [rand_net(), rand_net()]
    plans = plan_multi_failures(p, overlays, planner=plan_fr,
                                contention=1.0)
    assert len(plans) == 2
    for plan, t in plans:
        assert t < float("inf")
        plan_obj = plan
        assert plan_obj.scheme in ("fr", "ftr")
    # with zero contention both plans equal their standalone optima
    solo = [plan_fr(o, p).time for o in overlays]
    free = plan_multi_failures(p, overlays, planner=plan_fr, contention=0.0)
    for (pl, t), s in zip(free, solo):
        assert t == pytest.approx(s, rel=1e-6)
