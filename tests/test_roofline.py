"""Validation of the trip-count-aware HLO analyzer (launch/hlo_cost).

Runs in a subprocess because the probe needs multiple placeholder devices
(XLA locks the device count at first init and the rest of the suite runs
single-device).
"""
import json
import os
import subprocess
import sys

import pytest

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys, json
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hlo_cost

mesh = jax.make_mesh((4, 4), ("data", "model"))
sh = lambda *s: NamedSharding(mesh, P(*s))
M, K, N, L = 256, 512, 512, 8
out = {{}}

# 1) scan-free: analyzer vs XLA cost_analysis vs analytic
def f(x, w1, w2):
    return jnp.sum(jnp.tanh(x @ w1) @ w2)
c = jax.jit(f, in_shardings=(sh("data", None), sh(None, "model"),
                             sh("model", None))).lower(
    jax.ShapeDtypeStruct((M, K), jnp.float32),
    jax.ShapeDtypeStruct((K, N), jnp.float32),
    jax.ShapeDtypeStruct((N, K), jnp.float32)).compile()
got = hlo_cost.analyze(c.as_text())
out["free_analyzer"] = got.flops
out["free_xla"] = c.cost_analysis()["flops"]
out["free_analytic"] = (2 * M * K * N + 2 * M * N * K) / 16

# 2) scanned layers: trip counts must multiply
def g(ws, x):
    def body(h, w):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(body, x, ws)
    return jnp.sum(h)
c2 = jax.jit(g, in_shardings=(sh(None, None, "model"),
                              sh("data", None))).lower(
    jax.ShapeDtypeStruct((L, K, K), jnp.float32),
    jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()
out["scan_analyzer"] = hlo_cost.analyze(c2.as_text()).flops
out["scan_analytic"] = L * 2 * M * K * K / 16

# 3) grad of remat'd scan = exactly 4x fwd (fwd + recompute + 2 bwd dots)
def h(ws, x):
    def body(hh, w):
        return jnp.tanh(hh @ w), None
    o, _ = jax.lax.scan(jax.checkpoint(body), x, ws)
    return jnp.sum(o)
c3 = jax.jit(jax.grad(h), in_shardings=(sh(None, None, "model"),
                                        sh("data", None))).lower(
    jax.ShapeDtypeStruct((L, K, K), jnp.float32),
    jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()
out["grad_analyzer"] = hlo_cost.analyze(c3.as_text()).flops

# 4) collective parsing: all-reduce link bytes with ring model
txt = c.as_text()
stats = hlo_cost.analyze(txt)
out["coll_link"] = stats.total_link_bytes
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def probe():
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", _PROBE.format(src=src)],
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_analyzer_matches_xla_and_analytic_scanfree(probe):
    assert probe["free_analyzer"] == pytest.approx(probe["free_analytic"],
                                                   rel=1e-6)
    assert probe["free_analyzer"] == pytest.approx(probe["free_xla"],
                                                   rel=0.01)


def test_analyzer_multiplies_scan_trip_counts(probe):
    assert probe["scan_analyzer"] == pytest.approx(probe["scan_analytic"],
                                                   rel=1e-6)


def test_analyzer_grad_remat_is_4x_forward(probe):
    assert probe["grad_analyzer"] == pytest.approx(
        4.0 * probe["scan_analytic"], rel=1e-6)


def test_collectives_parsed(probe):
    # the psum over "model" of the (M/4, N) fp32 partial + scalar reduction
    assert probe["coll_link"] > 0
