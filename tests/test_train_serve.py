"""Train-loop (incl. failure recovery determinism) and serving-engine tests."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serve import Request, ServeEngine
from repro.train import (DataConfig, LoopConfig, OptimizerConfig, train)
from repro.models import init_params


def tiny_cfg():
    cfg = get_smoke_config("olmo-1b")
    return dataclasses.replace(cfg, num_layers=2, d_model=32, d_ff=64,
                               vocab_size=64, num_heads=2, num_kv_heads=2,
                               head_dim=16)


def test_loss_decreases():
    r = train(tiny_cfg(), DataConfig(batch=8, seq_len=32),
              OptimizerConfig(lr=3e-3),
              LoopConfig(steps=30, ckpt_every=50, log_every=100,
                         blocks_per_host=4),
              log=lambda s: None)
    first = np.mean(r.losses[:5])
    last = np.mean(r.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_failure_recovery_is_bit_identical():
    """A run with a mid-training host failure + EC regeneration + restore
    must converge to the same losses as an uninterrupted run (deterministic
    pipeline + exact state recovery)."""
    kw = dict(model_cfg=tiny_cfg(),
              data_cfg=DataConfig(batch=4, seq_len=32),
              opt_cfg=OptimizerConfig(lr=1e-3),
              log=lambda s: None)
    base = train(loop_cfg=LoopConfig(steps=24, ckpt_every=8, log_every=100,
                                     blocks_per_host=4), **kw)
    failed = train(loop_cfg=LoopConfig(steps=24, ckpt_every=8, log_every=100,
                                       blocks_per_host=4),
                   fail_at={13: 3}, scheme="ftr", **kw)
    assert len(failed.recoveries) == 1
    # the post-recovery replayed steps must match the uninterrupted run
    np.testing.assert_allclose(base.losses[-4:], failed.losses[-4:],
                               rtol=1e-5, atol=1e-6)


def test_serving_engine_batches():
    import jax
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=3, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5, rid=i)
            for i in range(5)]
    outs = eng.generate(reqs)
    assert len(outs) == 5
    for o in outs:
        assert 1 <= len(o.tokens) <= 5
        assert all(0 <= t < cfg.vocab_size for t in o.tokens)


def test_serving_greedy_matches_forward():
    """Greedy decode of the engine equals argmax of the parallel forward."""
    import jax
    from repro.models import embed_inputs, forward_hidden
    from repro.models.layers import apply_norm, logits_last
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = [5, 9, 11, 2]
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    out = eng.generate([Request(prompt=prompt, max_new_tokens=1)])[0]

    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    h = embed_inputs(cfg, params, batch)
    pos = jnp.arange(len(prompt), dtype=jnp.int32)
    h, _ = forward_hidden(cfg, params, h, positions=pos)
    h = apply_norm(cfg, params["final_norm"], h)
    want = int(jnp.argmax(logits_last(cfg, params["embed"], h)[0]))
    assert out.tokens[0] == want
