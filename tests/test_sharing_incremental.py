"""Incremental sharing engine (ISSUE 8): delta updates == full recompute.

The engine's contract is exact: a repair's nominal time is a pure function
of (residual links, true capacities, per-link user counts), so an
incremental ``recompute`` that only revisits repairs touching invalidated
links must land on bit-for-bit the nominals a full rescan computes.
``LinkShareModel(check=True)`` asserts exactly that after every
incremental pass — these tests drive randomized arrival / departure /
brownout / shock walks through a checked model (a seeded deterministic
sweep always runs; hypothesis widens it when installed), and run a whole
simulator under ``check_shares=True`` across the scenario knobs that
exercise every invalidation path.

The bank-aware migration satellite rides along: candidate-slate plumbing
(``RepairPolicy.replan_candidates``) and the off-by-default knob are
pinned here; the on/off *dynamics* split shows up in BENCH_fleet.json's
``..._bankmig`` row, and the off path staying bitwise is the golden
guard's job.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import CodeParams
from repro.fleet import (ActiveRepair, FixedPolicy, FlexiblePolicy,
                         FleetSimulator, LinkShareModel, Scenario,
                         make_policy)
from repro.fleet.scenario import uniform_matrix

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal local env; CI installs hypothesis
    HAVE_HYPOTHESIS = False

PARAMS = CodeParams.msr(n=12, k=3, d=6, M=600.0)


def _repair(node, links):
    return ActiveRepair(node=node, plan=None, ids=[node], links=links,
                        fail_time=0.0, start_time=0.0)


def _walk_checked_model(seed: int, steps: int = 120) -> None:
    """Random op walk through a check-mode model.  Every ``recompute``
    self-verifies the incremental nominals against a full rescan and
    raises on the first mismatch."""
    rng = np.random.default_rng(seed)
    n = 8
    caps = rng.uniform(0.5, 4.0, size=(n, n))
    np.fill_diagonal(caps, 0.0)
    model = LinkShareModel(caps, check=True)
    active = []
    reads = []
    for stepno in range(steps):
        op = int(rng.integers(0, 8))
        if op <= 1 or not active:
            # repair arrival (sometimes fully prepaid: empty links, which
            # only the _unlinked registry keeps alive)
            dst = int(rng.integers(0, n))
            d = int(rng.integers(0, 5))
            srcs = rng.choice([x for x in range(n) if x != dst],
                              size=d, replace=False)
            links = [((int(s), dst), float(rng.uniform(0.1, 1.0)))
                     for s in srcs]
            r = _repair(dst, links)
            model.acquire(links, r)
            active.append(r)
        elif op == 2:
            i = int(rng.integers(0, len(active)))
            r = active.pop(i)
            model.release(r.links, r)
        elif op == 3:
            # unregistered read traffic on top
            a, b = rng.choice(n, size=2, replace=False)
            links = [((int(a), int(b)), 1.0)]
            model.acquire(links)
            reads.append(links)
        elif op == 4 and reads:
            model.release(reads.pop(int(rng.integers(0, len(reads)))))
        elif op == 5:
            # brownout: one source's outgoing row changes
            node = int(rng.integers(0, n))
            model.caps[node, :] *= float(rng.uniform(0.5, 1.5))
            model.caps[node, node] = 0.0
            model.invalidate_source(node)
        elif op == 6:
            # capacity shock: the whole matrix changes
            model.caps[:] = rng.uniform(0.5, 4.0, size=(n, n))
            np.fill_diagonal(model.caps, 0.0)
            model.invalidate_all()
        # op == 7: pure recompute epoch (nothing touched — the
        # incremental pass must be a no-op that still verifies)
        model.recompute(active)
        for r in active:
            assert math.isfinite(r.nominal) or r.nominal == math.inf
            if not r.links:
                assert r.nominal == 0.0, "prepaid repair must stay at 0"


@pytest.mark.parametrize("seed", range(12))
def test_incremental_matches_full_recompute_sweep(seed):
    """Seeded deterministic walk: incremental == full rescan, bitwise."""
    _walk_checked_model(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10_000))
    def test_incremental_matches_full_recompute_property(seed):
        """Property form of the walk (wider random family)."""
        _walk_checked_model(seed, steps=60)


def test_checked_simulator_full_knobs():
    """A simulator under ``check_shares=True`` exercises every
    invalidation site (admission, completion, abort, reads, shocks,
    brownouts, migration replans) with the oracle comparing after each
    recompute — and its metrics equal the unchecked run's bitwise."""
    sc = Scenario(num_nodes=16, duration=250.0, failure_rate=8e-3,
                  capacity_model=uniform_matrix(0.3, 6.0),
                  max_concurrent=6, read_rate=0.5, read_duration=20.0,
                  shock_period=60.0, shock_lo=0.5, shock_hi=1.5,
                  carryover=True, migration=True,
                  degrade_rate=2e-2, degrade_mean_duration=15.0,
                  degrade_lo=0.3, degrade_hi=0.8)
    args = (sc, make_policy("flexible"), PARAMS)
    checked = FleetSimulator(*args, seed=3, check_shares=True).run()
    plain = FleetSimulator(*args, seed=3).run()
    assert checked.summary() == plain.summary()
    assert checked.completed > 0


def test_checked_model_catches_stale_nominals():
    """The oracle must actually bite: mutate capacities WITHOUT
    invalidating and the next registered-repair recompute asserts."""
    caps = np.full((4, 4), 2.0)
    np.fill_diagonal(caps, 0.0)
    model = LinkShareModel(caps, check=True)
    r = _repair(0, [((1, 0), 1.0)])
    model.acquire(r.links, r)
    model.recompute([r])            # clean first pass
    model.caps[1, 0] = 0.5          # stale: no invalidate_source(1)
    with pytest.raises(AssertionError):
        model.recompute([r])


# -- bank-aware migration satellite -----------------------------------------

def test_replan_candidates_default_is_single_proposal():
    """The base slate is exactly the one ``replan`` proposal per row."""
    pol = FixedPolicy("star")
    caps = np.full((2, PARAMS.d + 1, PARAMS.d + 1), 3.0)
    for c in caps:
        np.fill_diagonal(c, 0.0)
    slate = pol.replan_candidates(caps, PARAMS)
    proposals = pol.replan(caps, PARAMS)
    assert len(slate) == 2
    for cands, p in zip(slate, proposals):
        assert len(cands) == 1
        assert cands[0].time == p.time
        assert cands[0].scheme == p.scheme


def test_flexible_replan_candidates_race_all_schemes():
    """The flexible slate is one candidate per scheme, in preference
    order, covering every registered candidate scheme."""
    pol = FlexiblePolicy()
    caps = np.full((3, PARAMS.d + 1, PARAMS.d + 1), 3.0)
    for c in caps:
        np.fill_diagonal(c, 0.0)
    slate = pol.replan_candidates(caps, PARAMS)
    assert len(slate) == 3
    for cands in slate:
        assert [p.scheme for p in cands] == list(pol.schemes)


def test_bank_aware_migration_runs_and_default_off():
    """The knob defaults off; flipping it on yields a valid run (its
    bitwise-off guarantee is the fleet golden's job, exercised in
    BENCH_fleet.json's ``..._bankmig`` row)."""
    assert Scenario(num_nodes=8, duration=1.0).bank_aware_migration is False
    sc = Scenario(num_nodes=16, duration=300.0, failure_rate=8e-3,
                  capacity_model=uniform_matrix(0.3, 6.0),
                  max_concurrent=6, shock_period=40.0,
                  shock_lo=0.4, shock_hi=1.4,
                  carryover=True, migration=True)
    on = dataclasses.replace(sc, bank_aware_migration=True)
    m_off = FleetSimulator(sc, make_policy("flexible"), PARAMS, seed=5).run()
    m_on = FleetSimulator(on, make_policy("flexible"), PARAMS,
                          seed=5, check_shares=True).run()
    assert m_on.completed > 0 and m_off.completed > 0
    # same failure injections either way: the knob only changes which
    # replacement plan an in-flight migration adopts
    assert m_on.completed + m_on.aborted > 0
