"""Batched planning engine vs the scalar correctness oracle.

The batched engine (repro.core.batched) must reproduce the scalar planners'
outputs — regeneration time AND total repair traffic — on random
heterogeneous networks across the full storage trade-off (MSR / interior /
MBR operating points), and its results must not depend on how trials are
packed into batches.
"""
import math
import random

import numpy as np
import pytest

from repro.core import (CodeParams, OverlayNetwork, caps_tensor, get_scheme,
                        mbr_point, plan_tr)
from repro.core import batched as bt
from repro.core.lp import waterfill_max

SCHEME_NAMES = ("star", "fr", "tr", "ftr")


def _nets(seed: int, count: int, d: int, lo=10.0, hi=120.0):
    rng = random.Random(seed)
    out = []
    for _ in range(count):
        cap = [[0.0] * (d + 1) for _ in range(d + 1)]
        for u in range(d + 1):
            for v in range(d + 1):
                if u != v:
                    cap[u][v] = rng.uniform(lo, hi)
        out.append(OverlayNetwork(cap))
    return out


def _param_points():
    """MSR, interior and MBR operating points (n=12, k=3, d=6, M=600)."""
    M, k, d, n = 600.0, 3, 6, 12
    a_msr = M / k
    a_mbr, _ = mbr_point(M, k, d)
    return [
        ("msr", CodeParams(n=n, k=k, d=d, M=M, alpha=a_msr)),
        ("interior", CodeParams(n=n, k=k, d=d, M=M, alpha=0.5 * (a_msr + a_mbr))),
        ("mbr", CodeParams(n=n, k=k, d=d, M=M, alpha=a_mbr)),
    ]


@pytest.mark.parametrize("point,params", _param_points())
def test_batched_matches_scalar(point, params):
    """>= 50 seeded networks in total across the three operating points;
    every scheme's batched time/traffic matches the scalar planner 1e-6."""
    nets = _nets(seed=hash(point) % 10_000, count=20, d=params.d)
    caps = caps_tensor(nets)
    for s in SCHEME_NAMES:
        res = get_scheme(s).batched(caps, params)
        scalar = [get_scheme(s).scalar(net, params) for net in nets]
        np.testing.assert_allclose(
            res.times, [p.time for p in scalar], rtol=1e-9, atol=1e-6,
            err_msg=f"{s}@{point}: time mismatch")
        np.testing.assert_allclose(
            res.traffic, [p.total_traffic for p in scalar], rtol=1e-9,
            atol=1e-6, err_msg=f"{s}@{point}: traffic mismatch")


def test_batched_invariant_to_batch_order_and_size():
    """Lanes are independent: permuting the batch or splitting it into
    sub-batches must not change any trial's result."""
    params = CodeParams.msr(n=12, k=3, d=6, M=600.0)
    nets = _nets(seed=7, count=12, d=params.d)
    caps = caps_tensor(nets)
    perm = np.array([5, 0, 11, 3, 8, 1, 10, 2, 7, 4, 9, 6])
    for s in ("tr", "ftr"):
        full = get_scheme(s).batched(caps, params)
        shuffled = get_scheme(s).batched(caps[perm], params)
        np.testing.assert_allclose(shuffled.times, full.times[perm],
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(shuffled.traffic, full.traffic[perm],
                                   rtol=0, atol=1e-12)
        lo_half = get_scheme(s).batched(caps[:5], params)   # uneven split
        hi_half = get_scheme(s).batched(caps[5:], params)
        np.testing.assert_allclose(
            np.concatenate([lo_half.times, hi_half.times]), full.times,
            rtol=0, atol=1e-12)
        np.testing.assert_allclose(
            np.concatenate([lo_half.traffic, hi_half.traffic]), full.traffic,
            rtol=0, atol=1e-12)


def test_waterfill_batch_matches_scalar_leximin():
    """The chain-minimal batched water-fill computes the same (unique)
    leximin point as the scalar one-freeze-per-round lp.waterfill_max."""
    rng = random.Random(3)
    d, alpha = 7, 40.0
    parents_list, bounds_list = [], []
    for _ in range(25):
        parent = [0] * (d + 1)
        for u in range(1, d + 1):
            parent[u] = rng.randrange(0, u)  # u attaches above itself: a tree
        parents_list.append(parent)
        bounds_list.append([rng.uniform(5.0, 80.0) if rng.random() < 0.7
                            else math.inf for _ in range(d)])
    parents = np.array(parents_list)
    bnd = np.array(bounds_list)
    inc = bt.subtree_masks(parents)[:, 1:, :]
    got = bt.waterfill_batch(inc, bnd, alpha)
    for i in range(parents.shape[0]):
        laminar = [(list(np.flatnonzero(inc[i, u])), bnd[i, u])
                   for u in range(d) if math.isfinite(bnd[i, u])]
        want = waterfill_max([alpha] * d, laminar)
        np.testing.assert_allclose(got[i], want, rtol=1e-9, atol=1e-9)


def test_compare_schemes_engines_agree():
    """storage.compare_schemes: batched and scalar engines produce the same
    statistics on the same seeded trial sequence."""
    from repro.storage import compare_schemes, uniform

    params = CodeParams.msr(n=12, k=3, d=5, M=300.0)
    a = compare_schemes(params, uniform(), SCHEME_NAMES, trials=8, seed=11,
                        engine="batched")
    b = compare_schemes(params, uniform(), SCHEME_NAMES, trials=8, seed=11,
                        engine="scalar")
    for s in SCHEME_NAMES:
        assert a[s].mean_time == pytest.approx(b[s].mean_time, rel=1e-9)
        assert a[s].mean_norm_time == pytest.approx(b[s].mean_norm_time,
                                                    rel=1e-9)
        assert a[s].mean_traffic == pytest.approx(b[s].mean_traffic, rel=1e-9)
        assert a[s].mean_norm_traffic == pytest.approx(b[s].mean_norm_traffic,
                                                       rel=1e-9)


def test_compare_schemes_fallback_warns_once_and_reports_engine():
    """Schemes registered without a batched planner (rctree) must announce
    the scalar fallback exactly once per process and surface the engine that
    actually planned them in SchemeStats.engine.  Schemes WITH a batched
    planner — including shah since its vectorization — must never warn."""
    import warnings

    from repro.core import api
    from repro.storage import compare_schemes, uniform

    params = CodeParams.msr(n=12, k=3, d=4, M=120.0)
    api._warned_scalar_fallback.clear()
    with pytest.warns(RuntimeWarning,
                      match="no batched planner registered for 'rctree'"):
        stats = compare_schemes(params, uniform(), ("star", "rctree"),
                                trials=3, seed=0, engine="batched")
    assert stats["star"].engine == "batched"
    assert stats["rctree"].engine == "scalar"
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)  # would fail the call
        again = compare_schemes(params, uniform(), ("rctree", "shah"),
                                trials=2, seed=1, engine="batched")
    assert again["rctree"].engine == "scalar"
    assert again["shah"].engine == "batched"   # vectorized: no fallback
    scalar = compare_schemes(params, uniform(), ("star",), trials=2,
                             seed=1, engine="scalar")
    assert scalar["star"].engine == "scalar"


def test_rlnc_simulator_batched_planning_matches_scalar():
    """The fig10 data-plane simulator's planning step on the batched engine
    reproduces the scalar oracle's node states exactly."""
    from repro.storage import RlncSimulator, uniform

    params = CodeParams.msr(n=8, k=2, d=4, M=6.0)
    sims = {e: RlncSimulator(params, seed=5, engine=e)
            for e in ("batched", "scalar")}
    for _ in range(3):
        for sim in sims.values():
            sim.repair_round("ftr", uniform())
    a, b = sims["batched"], sims["scalar"]
    for node in a.nodes:
        np.testing.assert_array_equal(a.nodes[node].vectors,
                                      b.nodes[node].vectors)
    assert a.reconstruction_probability() == b.reconstruction_probability()
    # the fig10 driver batches a whole trial's planning into one call;
    # probabilities must match the round-by-round scalar oracle exactly
    from repro.storage import reconstruction_vs_rounds

    pb = reconstruction_vs_rounds(params, "ftr", uniform(), rounds=3,
                                  trials=1, seed=9, engine="batched")
    ps = reconstruction_vs_rounds(params, "ftr", uniform(), rounds=3,
                                  trials=1, seed=9, engine="scalar")
    assert pb == ps
    # subset sampling draws from the same rng stream as round sampling, so
    # the driver must take the order-preserving path there — still equal
    kw = dict(rounds=3, trials=1, seed=9, subset_samples=5)
    assert (reconstruction_vs_rounds(params, "ftr", uniform(),
                                     engine="batched", **kw)
            == reconstruction_vs_rounds(params, "ftr", uniform(),
                                        engine="scalar", **kw))


# ---------------------------------------------------------------------------
# plan_tr tie-break regression (crafted capacity matrix)
# ---------------------------------------------------------------------------

def _tiebreak_net() -> OverlayNetwork:
    """d = 3 overlay engineered so Algorithm 1's second step produces an
    EXACT time tie between attaching v2 to the newcomer (c(2,0) = 24,
    t = max(12/24, 12/48) = 0.5) and relaying v2 through v1 (c(2,1) = 48,
    t = max(12/48, 24/48) = 0.5).  The faster link must win -> parent[2] = 1.

    The reverse direction c(0,2) = 48 > c(2,0) and c(1,2) = 24 < c(2,1) are
    set adversarially: a greedy comparing capacities in the wrong (parent ->
    child) direction, or one ignoring capacities on ties, would instead pick
    parent[2] = 0.
    """
    d = 3
    cap = [[5.0] * (d + 1) for _ in range(d + 1)]
    for i in range(d + 1):
        cap[i][i] = 0.0
    cap[1][0] = 48.0
    cap[2][0] = 24.0
    cap[2][1] = 48.0
    cap[3][0] = 6.0
    cap[3][1] = 5.0
    cap[3][2] = 5.0
    cap[0][2] = 48.0   # adversarial reverse directions
    cap[1][2] = 24.0
    return OverlayNetwork(cap)


TIEBREAK_PARAMS = CodeParams(n=5, k=2, d=3, M=60.0, alpha=45.0)


def test_plan_tr_tie_prefers_faster_link():
    assert TIEBREAK_PARAMS.beta == pytest.approx(12.0)
    plan = plan_tr(_tiebreak_net(), TIEBREAK_PARAMS)
    assert plan.parent == {1: 0, 2: 1, 3: 0}
    plan.validate(_tiebreak_net())


def test_plan_tr_batch_matches_tiebreak():
    caps = caps_tensor([_tiebreak_net()])
    res = get_scheme("tr").batched(caps, TIEBREAK_PARAMS)
    assert res.parents[0].tolist() == [0, 0, 1, 0]
    scalar = plan_tr(_tiebreak_net(), TIEBREAK_PARAMS)
    assert res.times[0] == pytest.approx(scalar.time)
    assert res.traffic[0] == pytest.approx(scalar.total_traffic)
