"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step on CPU, asserting output shapes and no NaNs; decode smoke for
autoregressive archs; analytic param_count vs actual tree size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          prefill)


def make_batch(cfg, B=2, S=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {}
    if cfg.frontend in ("tokens", "patch_embed"):
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
        labels = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
        if cfg.frontend == "patch_embed":
            n = cfg.num_frontend_tokens
            batch["patch_embeds"] = jax.random.normal(
                k, (B, n, cfg.d_model), jnp.float32)
            labels = labels.at[:, :n].set(-1)
        batch["labels"] = labels
    else:  # frame_embed
        batch["frames"] = jax.random.normal(k, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return batch


def tree_size(tree):
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_formula(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert tree_size(params) == cfg.param_count(), (
        f"{arch}: actual {tree_size(params)} != formula {cfg.param_count()}")


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_smoke_config(a).causal])
def test_prefill_decode_consistency(arch):
    """Prefill+decode logits must match the full-sequence forward."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S)
    cache = init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits_p, cache = jax.jit(
        lambda p, b, c: prefill(cfg, p, b, c))(params, batch, cache)
    assert np.isfinite(np.asarray(logits_p)).all()

    # decode two tokens; check shapes/finiteness and cache movement
    tok = jnp.full((B, 1), 3, jnp.int32)
    logits_d, cache = jax.jit(
        lambda p, c, t, i: decode_step(cfg, p, c, t, i))(
            params, cache, tok, jnp.int32(S))
    assert logits_d.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_d)).all()
    logits_d2, _ = jax.jit(
        lambda p, c, t, i: decode_step(cfg, p, c, t, i))(
            params, cache, tok, jnp.int32(S + 1))
    assert np.isfinite(np.asarray(logits_d2)).all()


def test_dense_decode_matches_full_forward():
    """Strict consistency on one dense arch: teacher-forced decode equals
    the parallel forward's next-token logits."""
    from repro.models import embed_inputs, forward_hidden
    from repro.models.layers import apply_norm, logits_last
    cfg = get_smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    batch = make_batch(cfg, B=B, S=S, key=5)
    toks = batch["tokens"]

    # parallel forward logits at the last position
    h = embed_inputs(cfg, params, batch)
    pos = jnp.arange(S, dtype=jnp.int32)
    h, _ = forward_hidden(cfg, params, h, positions=pos)
    h = apply_norm(cfg, params["final_norm"], h)
    want = logits_last(cfg, params["embed"], h)

    # prefill on S-1 tokens, then decode token S-1
    batch_p = {"tokens": toks[:, :S - 1], "labels": batch["labels"][:, :S - 1]}
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache = prefill(cfg, params, batch_p, cache)
    got, _ = decode_step(cfg, params, cache, toks[:, S - 1:S],
                         jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
