"""Checkpoint-shard regeneration walkthrough on a simulated 2-pod fleet.

Saves an erasure-coded train-state checkpoint over 8 hosts, kills one host,
compares the repair plans of STAR / FR / TR / FTR on the sampled
heterogeneous overlay (fast intra-pod, slow cross-pod links + background
traffic), executes the winner on real GF(2^8) shards, and proves the state
restores bit-identically.

Run:  PYTHONPATH=src python examples/regenerate_checkpoint.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.ft import ECCheckpoint, ErasureCoder, Fleet, FleetConfig
from repro.models import init_params


def main():
    cfg = get_smoke_config("yi-6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "step": np.int32(1000)}

    fleet = Fleet(FleetConfig(num_pods=2, hosts_per_pod=8,
                              straggler_fraction=0.2), seed=4)
    coder = ErasureCoder(n=8, k=4, d=6, blocks_per_host=16, seed=4)
    # recovery group spans both pods (survives a pod loss of <= n-k hosts)
    hosts = [0, 1, 2, 3, 8, 9, 10, 11]
    ckpt = ECCheckpoint(fleet, coder, hosts, seed=4)
    ckpt.save(state, step=1000)
    nbytes = ckpt.group.block_bytes * coder.M
    print(f"checkpoint: {nbytes/1e6:.2f} MB coded as (n=8, k=4, d=6) over "
          f"hosts {hosts} (pods {[fleet.pod_of(h) for h in hosts]})")

    failed = 9
    print(f"\nhost {failed} fails (pod {fleet.pod_of(failed)})")
    log = ckpt.on_host_failure(failed, scheme="auto")
    d = log.decision
    print(f"providers: {d.providers}")
    print("predicted regeneration time per scheme:")
    for name, t in sorted(d.alternatives.items(), key=lambda kv: kv[1]):
        marker = "  <- chosen" if name == d.plan.scheme else ""
        print(f"  {name:5s} {t:8.4f} s{marker}")
    speedup = d.alternatives["star"] / d.predicted_s
    print(f"regeneration {speedup:.2f}x faster than uniform STAR")
    print(f"blocks moved: {log.report.blocks_moved:.0f} "
          f"(full any-k reconstruction would move {coder.M})")

    restored = ckpt.restore([failed, 0, 2, 10])
    leaves_a = jax.tree_util.tree_leaves(state)
    leaves_b = jax.tree_util.tree_leaves(restored)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves_a, leaves_b))
    print("state restored bit-identically from a set containing the "
          "regenerated host: OK")


if __name__ == "__main__":
    main()
