"""Quickstart: the paper's Fig. 1 worked example, end to end.

Builds the 4-provider overlay (70/50/20/10 Mbps direct links, a 35 Mbps
v4->v1 side link), plans a regeneration of the failed node with the four
paper schemes (plus the MDS-breaking RCTREE baseline) through the unified
planner API (``repro.core.plan``), verifies the MDS property of each plan
via the information-flow graph, plans a small Monte-Carlo batch with
``plan_many`` on the vectorized engine across the pinned batched family,
and executes the FTR plan on real GF(2^8)-coded data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random

import numpy as np

from repro.coding import GF8, RLNC
from repro.core import (CodeParams, InfoFlowGraph, OverlayNetwork,
                        caps_tensor, event_from_plan, plan, plan_many,
                        scheme_names)

# --- Fig. 1 setup: n=5, k=2, d=4, M=480 Mb, alpha=240, beta=80 --------------
P = CodeParams.msr(n=5, k=2, d=4, M=480.0)
net = OverlayNetwork.star_only([70.0, 50.0, 20.0, 10.0], cross=5.0)
net.cap[4][1] = 35.0

print(f"(n={P.n}, k={P.k}) MDS code, d={P.d} providers, "
      f"M={P.M:.0f} Mb, alpha={P.alpha:.0f} Mb, beta={P.beta:.0f} Mb\n")

print(f"{'scheme':8s} {'time (s)':>9s} {'traffic (Mb)':>13s}  tree")
for scheme in ("star", "fr", "tr", "ftr"):
    p = plan(net, P, scheme)
    p.validate(net)
    tree = " ".join(f"v{u}->v{pa}" if pa else f"v{u}->nc"
                    for u, pa in sorted(p.parent.items()))
    print(f"{p.scheme:8s} {p.time:9.3f} {p.total_traffic:13.1f}  {tree}")

    # MDS check: fail node 5, repair, then every k-subset must reach M
    g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
    g.fail_and_repair(5, event_from_plan(p, 6, [1, 2, 3, 4]))
    worst, flow = g.worst_collector()
    assert flow >= P.M - 1e-6, (p.scheme, worst, flow)
print("\nall four schemes preserve the MDS property (min-cut >= M)")

bad = plan(net, P, "rctree")
g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
g.fail_and_repair(5, event_from_plan(bad, 6, [1, 2, 3, 4]))
worst, flow = g.worst_collector()
print(f"RCTREE [7] min-cut through {worst} = {flow:.0f} Mb < M={P.M:.0f} "
      f"-> MDS broken (Appendix A)\n")

# --- Monte-Carlo batch through the vectorized engine ------------------------
# plan_many plans a whole batch of sampled overlays in one call per scheme.
# The family is PINNED here (not enumerated from the registry) so that a
# scheme losing its batched planner fails loudly: the scalar-fallback
# RuntimeWarning errors under CI's -W error::RuntimeWarning run, and the
# engine assert catches it even without the warning filter.
BATCHED_FAMILY = ("star", "fr", "tr", "ftr", "shah")
assert set(BATCHED_FAMILY) <= set(scheme_names()), "registry lost a scheme"
rng = random.Random(0)
batch = [OverlayNetwork([[0.0 if u == v else rng.uniform(10.0, 120.0)
                          for v in range(P.d + 1)] for u in range(P.d + 1)])
         for _ in range(16)]
caps = caps_tensor(batch)
print("mean regeneration time over a 16-overlay Monte-Carlo batch "
      "(engine='batched'):")
for scheme in BATCHED_FAMILY:
    res = plan_many(caps, P, scheme, engine="batched")
    assert res.engine == "batched", \
        f"{scheme} silently took the {res.engine} path"
    print(f"  {scheme:6s} {res.times.mean():7.3f} s   "
          f"[{res.engine} engine]")
print()

# --- execute the FTR plan on real coded blocks ------------------------------
print("executing the FTR plan on real GF(2^8)-coded blocks...")
rng = np.random.default_rng(0)
rl = RLNC(GF8)
M_blocks, blk = 8, 64                       # 8 blocks of 64 bytes
alpha_b = M_blocks // P.k                   # 4 blocks/node
file_blocks = GF8.random((M_blocks, blk), rng)
nodes = dict(enumerate(rl.distribute(file_blocks, P.n, alpha_b, rng), 1))

ftr_plan = plan(net, P, "ftr")
scalefactor = alpha_b / P.alpha             # paper Mb -> demo blocks
import math
# produce bottom-up along the tree
children = {}
for u, p in ftr_plan.parent.items():
    children.setdefault(p, []).append(u)

def produce(u):
    own = rl.encode(nodes[u],
                    math.ceil(ftr_plan.betas[u - 1] * scalefactor - 1e-9), rng)
    recv = None
    for ch in children.get(u, []):
        part = produce(ch)
        recv = part if recv is None else recv.concat(part)
    if recv is None:
        return own
    quota = math.ceil(ftr_plan.flows[(u, ftr_plan.parent[u])] * scalefactor
                      - 1e-9)
    return rl.relay(recv, own, quota, rng)

received = None
for r in children.get(0, []):
    part = produce(r)
    received = part if received is None else received.concat(part)
newcomer = rl.regenerate(received, alpha_b, rng)
ok = rl.can_reconstruct([newcomer, nodes[3]], M_blocks)
got = rl.reconstruct([newcomer, nodes[3]], M_blocks)
assert ok and np.array_equal(got, file_blocks)
print("newcomer + v3 reconstruct the original file: OK")
