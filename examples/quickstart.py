"""Quickstart: the paper's Fig. 1 worked example, end to end.

Builds the 4-provider overlay (70/50/20/10 Mbps direct links, a 35 Mbps
v4->v1 side link), plans a regeneration of the failed node with all four
schemes, verifies the MDS property of each plan via the information-flow
graph, and executes the FTR plan on real GF(2^8)-coded data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.coding import GF8, RLNC
from repro.core import (CodeParams, InfoFlowGraph, OverlayNetwork,
                        event_from_plan, plan_fr, plan_ftr, plan_rctree,
                        plan_star, plan_tr)

# --- Fig. 1 setup: n=5, k=2, d=4, M=480 Mb, alpha=240, beta=80 --------------
P = CodeParams.msr(n=5, k=2, d=4, M=480.0)
net = OverlayNetwork.star_only([70.0, 50.0, 20.0, 10.0], cross=5.0)
net.cap[4][1] = 35.0

print(f"(n={P.n}, k={P.k}) MDS code, d={P.d} providers, "
      f"M={P.M:.0f} Mb, alpha={P.alpha:.0f} Mb, beta={P.beta:.0f} Mb\n")

print(f"{'scheme':8s} {'time (s)':>9s} {'traffic (Mb)':>13s}  tree")
for planner in (plan_star, plan_fr, plan_tr, plan_ftr):
    plan = planner(net, P)
    plan.validate(net)
    tree = " ".join(f"v{u}->v{p}" if p else f"v{u}->nc"
                    for u, p in sorted(plan.parent.items()))
    print(f"{plan.scheme:8s} {plan.time:9.3f} {plan.total_traffic:13.1f}  {tree}")

    # MDS check: fail node 5, repair, then every k-subset must reach M
    g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
    g.fail_and_repair(5, event_from_plan(plan, 6, [1, 2, 3, 4]))
    worst, flow = g.worst_collector()
    assert flow >= P.M - 1e-6, (plan.scheme, worst, flow)
print("\nall four schemes preserve the MDS property (min-cut >= M)")

bad = plan_rctree(net, P)
g = InfoFlowGraph(P, initial_nodes=[1, 2, 3, 4, 5])
g.fail_and_repair(5, event_from_plan(bad, 6, [1, 2, 3, 4]))
worst, flow = g.worst_collector()
print(f"RCTREE [7] min-cut through {worst} = {flow:.0f} Mb < M={P.M:.0f} "
      f"-> MDS broken (Appendix A)\n")

# --- execute the FTR plan on real coded blocks ------------------------------
print("executing the FTR plan on real GF(2^8)-coded blocks...")
rng = np.random.default_rng(0)
rl = RLNC(GF8)
M_blocks, blk = 8, 64                       # 8 blocks of 64 bytes
alpha_b = M_blocks // P.k                   # 4 blocks/node
file_blocks = GF8.random((M_blocks, blk), rng)
nodes = dict(enumerate(rl.distribute(file_blocks, P.n, alpha_b, rng), 1))

plan = plan_ftr(net, P)
scalefactor = alpha_b / P.alpha             # paper Mb -> demo blocks
import math
# produce bottom-up along the tree
children = {}
for u, p in plan.parent.items():
    children.setdefault(p, []).append(u)

def produce(u):
    own = rl.encode(nodes[u], math.ceil(plan.betas[u - 1] * scalefactor - 1e-9), rng)
    recv = None
    for ch in children.get(u, []):
        part = produce(ch)
        recv = part if recv is None else recv.concat(part)
    if recv is None:
        return own
    quota = math.ceil(plan.flows[(u, plan.parent[u])] * scalefactor - 1e-9)
    return rl.relay(recv, own, quota, rng)

received = None
for r in children.get(0, []):
    part = produce(r)
    received = part if received is None else received.concat(part)
newcomer = rl.regenerate(received, alpha_b, rng)
ok = rl.can_reconstruct([newcomer, nodes[3]], M_blocks)
got = rl.reconstruct([newcomer, nodes[3]], M_blocks)
assert ok and np.array_equal(got, file_blocks)
print("newcomer + v3 reconstruct the original file: OK")
