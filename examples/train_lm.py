"""End-to-end driver: train a causal LM with erasure-coded checkpointing,
inject a host failure mid-run, regenerate the lost checkpoint shard with the
paper's FTR planner, restore, and finish training.

Defaults are CPU-sized (~1M params, 120 steps, a few minutes).  On real
hardware scale up with --preset 100m (~110M params).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 120] [--preset tiny]
"""
import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.train import DataConfig, LoopConfig, OptimizerConfig, train

PRESETS = {
    # ~1.1M params: a couple of minutes on one CPU core
    "tiny": dict(num_layers=2, d_model=128, d_ff=256, vocab_size=512,
                 num_heads=4, num_kv_heads=4, head_dim=32),
    # ~110M params (olmo-style): for real accelerators
    "100m": dict(num_layers=12, d_model=768, d_ff=3072, vocab_size=32768,
                 num_heads=12, num_kv_heads=12, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-step", type=int, default=70)
    ap.add_argument("--fail-host", type=int, default=3)
    ap.add_argument("--scheme", default="auto",
                    choices=["auto", "star", "fr", "tr", "ftr"])
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), **PRESETS[args.preset])
    res = train(
        cfg,
        DataConfig(batch=args.batch, seq_len=args.seq),
        OptimizerConfig(lr=1e-3),
        LoopConfig(steps=args.steps, ckpt_every=25, log_every=10,
                   blocks_per_host=8),
        fail_at={args.fail_step: args.fail_host},
        scheme=args.scheme,
    )
    print(f"\nran {res.steps_run} steps "
          f"(incl. replay after {len(res.recoveries)} recovery); "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    for rec in res.recoveries:
        d = rec.decision
        print(f"recovery: scheme={d.plan.scheme} predicted={d.predicted_s:.3f}s"
              f" alternatives=" +
              " ".join(f"{k}:{v:.3f}s" for k, v in d.alternatives.items()))


if __name__ == "__main__":
    main()
