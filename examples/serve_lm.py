"""Batched serving demo: prefill + continuous decode on the serving engine.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine


def main():
    cfg = dataclasses.replace(
        get_smoke_config("qwen2.5-14b"),  # GQA-style smoke config
        num_layers=2, d_model=128, d_ff=256, vocab_size=512,
        num_heads=8, num_kv_heads=2, head_dim=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=4, max_len=96, seed=1)

    reqs = [Request(prompt=[(7 * i + j) % cfg.vocab_size for j in range(4 + i)],
                    max_new_tokens=12, temperature=0.0 if i % 2 else 0.8,
                    rid=i)
            for i in range(10)]
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(o.tokens) for o in outs)
    print(f"served {len(reqs)} requests / {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s on one CPU core)")
    for o in outs:
        print(f"  rid={o.rid}: {o.tokens}")


if __name__ == "__main__":
    main()
