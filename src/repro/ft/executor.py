"""Execute a regeneration plan on real erasure-coded shard data.

Runs the plan's tree bottom-up: leaf providers encode beta_i random
combinations of their alpha stored blocks, interior providers re-encode
(received ++ own) down to the edge flow, the newcomer stores alpha
combinations of everything received (paper Section II-A / V-A).  Fractional
betas/flows ceil-round (Section III-C).  Also produces a simulated transfer
timeline from the overlay bandwidths for reporting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.coding import CodedBlocks, RLNC, GF8
from repro.core import OverlayNetwork, RepairPlan
from .erasure import EncodedGroup


@dataclasses.dataclass
class ExecutionReport:
    regenerated_host: int
    blocks_moved: float
    predicted_s: float
    per_edge_s: Dict[str, float]


def execute_regeneration(group: EncodedGroup, plan: RepairPlan,
                         overlay: OverlayNetwork, failed_host: int,
                         provider_hosts: List[int],
                         rng: Optional[np.random.Generator] = None,
                         ) -> ExecutionReport:
    """Regenerates ``failed_host``'s shard in ``group`` (in place)."""
    rng = rng or np.random.default_rng(0)
    rl = RLNC(GF8)
    alpha = int(round(group.params.alpha))
    idmap = {i: h for i, h in enumerate(provider_hosts, start=1)}

    children: Dict[int, List[int]] = {}
    for u, p in plan.parent.items():
        children.setdefault(p, []).append(u)

    def produce(u: int) -> CodedBlocks:
        own_quota = int(math.ceil(plan.betas[u - 1] - 1e-9))
        send_quota = int(math.ceil(plan.flows[(u, plan.parent[u])] - 1e-9))
        own = rl.encode(group.shards[idmap[u]], own_quota, rng)
        recv: Optional[CodedBlocks] = None
        for ch in children.get(u, []):
            part = produce(ch)
            recv = part if recv is None else recv.concat(part)
        if recv is None:
            out = own
        else:
            pool = recv.concat(own)
            out = (rl.relay(recv, own, send_quota, rng)
                   if pool.num > send_quota else pool)
        if out.num > send_quota:
            out = CodedBlocks(out.vectors[:send_quota],
                              out.payload[:send_quota])
        return out

    received: Optional[CodedBlocks] = None
    for r in children.get(0, []):
        part = produce(r)
        received = part if received is None else received.concat(part)
    assert received is not None, "plan tree has no edges into the newcomer"
    group.shards[failed_host] = rl.regenerate(received, alpha, rng)

    per_edge = {}
    for (u, v), f in plan.flows.items():
        c = overlay.c(u, v)
        per_edge[f"{idmap.get(u, u)}->{idmap.get(v, 'newcomer')}"] = (
            math.ceil(f) / c if c > 0 else float("inf"))
    return ExecutionReport(regenerated_host=failed_host,
                           blocks_moved=sum(math.ceil(f)
                                            for f in plan.flows.values()),
                           predicted_s=max(per_edge.values()),
                           per_edge_s=per_edge)
