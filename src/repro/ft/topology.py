"""TPU-fleet network topology for recovery planning (DESIGN.md §3).

The paper's overlays are generic (PlanetLab U[10,120] Mbps); a TPU fleet is
*tiered*: hosts inside a pod see fast links (ICI/within-cluster fabric),
hosts in different pods talk over shared DCN.  Background traffic (other
jobs, data ingest, checkpoint fan-in) modulates available bandwidth per
link — the heterogeneity regime where FR/TR/FTR matter.

``snapshot_overlay`` samples the *currently available* end-to-end bandwidth
between a newcomer host and its d providers, which is exactly the overlay
G(V, E) the planners consume.  Stragglers are modelled as hosts whose
outgoing available bandwidth is scaled down persistently.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

from repro.core import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    num_pods: int = 2
    hosts_per_pod: int = 64
    # effective host-to-host bandwidths in GB/s (NIC/fabric level, not ICI
    # chip links): same-pod fast tier, cross-pod DCN tier
    intra_pod_gbps: float = 25.0
    inter_pod_gbps: float = 6.25
    # available-bandwidth multiplier ~ U[lo, hi] per directed link per
    # snapshot (background traffic)
    load_lo: float = 0.15
    load_hi: float = 1.0
    # persistent per-host straggler multiplier (1.0 = healthy)
    straggler_fraction: float = 0.05
    straggler_slowdown: float = 0.1


class Fleet:
    """Host inventory with pod placement and straggler state."""

    def __init__(self, cfg: FleetConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = random.Random(seed)
        self.num_hosts = cfg.num_pods * cfg.hosts_per_pod
        self.straggle: Dict[int, float] = {}
        for h in range(self.num_hosts):
            if self.rng.random() < cfg.straggler_fraction:
                self.straggle[h] = cfg.straggler_slowdown

    def pod_of(self, host: int) -> int:
        return host // self.cfg.hosts_per_pod

    def mark_straggler(self, host: int, slowdown: float) -> None:
        self.straggle[host] = slowdown

    def heal(self, host: int) -> None:
        self.straggle.pop(host, None)

    def base_bw(self, u: int, v: int) -> float:
        c = (self.cfg.intra_pod_gbps if self.pod_of(u) == self.pod_of(v)
             else self.cfg.inter_pod_gbps)
        return c * self.straggle.get(u, 1.0)

    def capacity_matrix(self, hosts: Sequence[int], block_mb: float = 1.0,
                        rng=None) -> List[List[float]]:
        """Available-bandwidth snapshot among ``hosts`` in blocks/sec.

        Entry [i][j] is the current host[i] -> host[j] bandwidth: the tiered
        base rate times a per-link background-load draw.  ``rng`` may be a
        ``random.Random`` or a ``numpy.random.Generator`` (both expose
        ``uniform(lo, hi)``); the fleet simulator passes the latter.  This
        is the sampler both ``snapshot_overlay`` (single repair) and
        ``repro.fleet``'s tiered scenario (whole cluster) are built on.
        """
        rng = rng if rng is not None else self.rng
        m = len(hosts)
        cap = [[0.0] * m for _ in range(m)]
        for i, u in enumerate(hosts):
            for j, v in enumerate(hosts):
                if i == j:
                    continue
                avail = self.base_bw(u, v) * float(
                    rng.uniform(self.cfg.load_lo, self.cfg.load_hi))
                cap[i][j] = avail * 1000.0 / block_mb   # GB/s -> MB-blocks/s
        return cap

    def snapshot_overlay(self, newcomer: int, providers: Sequence[int],
                         block_mb: float = 1.0,
                         rng: Optional[random.Random] = None,
                         ) -> OverlayNetwork:
        """Overlay in blocks/sec for a repair: node 0 = newcomer, 1..d =
        providers.  ``block_mb`` converts GB/s into block units."""
        ids = [newcomer] + list(providers)
        return OverlayNetwork(self.capacity_matrix(ids, block_mb, rng))
