"""Fault-tolerance layer: erasure-coded checkpoints whose repair engine is
the paper's heterogeneity-aware regeneration planning (DESIGN.md §2)."""
from .topology import Fleet, FleetConfig
from .erasure import ErasureCoder, EncodedGroup, bytes_to_tree, tree_to_bytes
from .planner import RecoveryDecision, choose_providers, plan_recovery
from .executor import ExecutionReport, execute_regeneration
from .checkpoint import ECCheckpoint, RecoveryLog

__all__ = ["Fleet", "FleetConfig", "ErasureCoder", "EncodedGroup",
           "bytes_to_tree", "tree_to_bytes", "RecoveryDecision",
           "choose_providers", "plan_recovery", "ExecutionReport",
           "execute_regeneration", "ECCheckpoint", "RecoveryLog"]
