"""Erasure coding of checkpoint pytrees into per-host shards.

A checkpoint (params + optimizer state pytree) is flattened into a byte
buffer, split into M equal blocks and RLNC-encoded into n * alpha coded
blocks over a *recovery group* of n hosts (alpha = M/k each, MSR layout).
Any k hosts reconstruct; a lost host is regenerated from d survivors with
the paper's planners (repro.core) instead of full reconstruction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.coding import GF8, RLNC, CodedBlocks
from repro.core import CodeParams


@dataclasses.dataclass
class TreeSpec:
    """Enough structure to rebuild the pytree from bytes."""
    treedef: Any
    shapes: List[Tuple[int, ...]]
    dtypes: List[Any]
    sizes: List[int]          # byte length per leaf
    total_bytes: int


def tree_to_bytes(tree: Any) -> Tuple[np.ndarray, TreeSpec]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    bufs = [a.tobytes() for a in arrs]
    flat = b"".join(bufs)
    spec = TreeSpec(treedef=treedef,
                    shapes=[a.shape for a in arrs],
                    dtypes=[a.dtype for a in arrs],
                    sizes=[len(b) for b in bufs],
                    total_bytes=len(flat))
    return np.frombuffer(flat, dtype=np.uint8), spec


def bytes_to_tree(buf: np.ndarray, spec: TreeSpec) -> Any:
    out, off = [], 0
    raw = buf.tobytes()
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        out.append(np.frombuffer(raw[off:off + size], dtype=dtype
                                 ).reshape(shape))
        off += size
    return jax.tree_util.tree_unflatten(spec.treedef, out)


@dataclasses.dataclass
class EncodedGroup:
    """One recovery group: n host shards of an (n, k, d)-coded buffer."""
    params: CodeParams
    block_bytes: int
    payload_bytes: int                  # original length (pre-padding)
    shards: Dict[int, CodedBlocks]      # host id -> alpha coded blocks

    def live_hosts(self) -> List[int]:
        return sorted(self.shards)


class ErasureCoder:
    def __init__(self, n: int = 8, k: int = 4, d: int = 6,
                 blocks_per_host: int = 16, seed: int = 0):
        # MSR layout: alpha = M/k blocks per host
        self.n, self.k, self.d = n, k, d
        self.alpha = blocks_per_host
        self.M = self.alpha * k
        self.rl = RLNC(GF8)
        self.rng = np.random.default_rng(seed)

    def encode(self, buf: np.ndarray, hosts: Sequence[int]) -> EncodedGroup:
        assert len(hosts) == self.n
        payload = len(buf)
        block_bytes = math.ceil(payload / self.M)
        padded = np.zeros(block_bytes * self.M, dtype=np.uint8)
        padded[:payload] = buf
        blocks = padded.reshape(self.M, block_bytes)
        node_blocks = self.rl.distribute(blocks, self.n, self.alpha, self.rng)
        params = CodeParams(n=self.n, k=self.k, d=self.d, M=float(self.M),
                            alpha=float(self.alpha))
        return EncodedGroup(params=params, block_bytes=block_bytes,
                            payload_bytes=payload,
                            shards=dict(zip(hosts, node_blocks)))

    def reconstruct(self, group: EncodedGroup,
                    hosts: Optional[Sequence[int]] = None) -> np.ndarray:
        hosts = list(hosts) if hosts is not None else group.live_hosts()[: self.k]
        nodes = [group.shards[h] for h in hosts]
        blocks = self.rl.reconstruct(nodes, self.M)
        return blocks.reshape(-1)[: group.payload_bytes]
