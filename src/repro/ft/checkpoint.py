"""Erasure-coded distributed checkpointing with fast heterogeneity-aware
regeneration (the paper's technique as a first-class framework feature).

``ECCheckpoint.save`` shards a train-state pytree over a recovery group of
hosts; ``on_host_failure`` regenerates the lost shard via the FR/TR/FTR
planner (NOT full any-k reconstruction — that is the whole point: the
regeneration moves ~M/k * d/(d-k+1) blocks instead of M); ``restore``
rebuilds the pytree from any k live hosts.  ``reshard`` (elastic) re-encodes
onto a different group size.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .erasure import EncodedGroup, ErasureCoder, TreeSpec, bytes_to_tree, \
    tree_to_bytes
from .executor import ExecutionReport, execute_regeneration
from .planner import RecoveryDecision, choose_providers, plan_recovery
from .topology import Fleet


@dataclasses.dataclass
class RecoveryLog:
    decision: RecoveryDecision
    report: ExecutionReport
    wall_s: float


class ECCheckpoint:
    """One checkpointed train state, erasure-coded over fleet hosts."""

    def __init__(self, fleet: Fleet, coder: ErasureCoder,
                 hosts: Sequence[int], seed: int = 0):
        assert len(hosts) == coder.n
        self.fleet = fleet
        self.coder = coder
        self.hosts = list(hosts)
        self.rng = random.Random(seed)
        self.np_rng = np.random.default_rng(seed)
        self.group: Optional[EncodedGroup] = None
        self.spec: Optional[TreeSpec] = None
        self.step: int = -1
        self.recoveries: List[RecoveryLog] = []

    # -- save / restore ------------------------------------------------------

    def save(self, state: Any, step: int) -> None:
        buf, self.spec = tree_to_bytes(state)
        self.group = self.coder.encode(buf, self.hosts)
        self.step = step

    def restore(self, from_hosts: Optional[Sequence[int]] = None) -> Any:
        assert self.group is not None and self.spec is not None
        buf = self.coder.reconstruct(self.group, from_hosts)
        return bytes_to_tree(buf, self.spec)

    # -- failure handling ------------------------------------------------------

    def on_host_failure(self, failed: int, replacement: Optional[int] = None,
                        scheme: str = "auto",
                        block_mb: Optional[float] = None) -> RecoveryLog:
        """Regenerate the failed host's shard onto ``replacement`` (defaults
        to reusing the host id, i.e. the restarted machine)."""
        assert self.group is not None
        assert failed in self.group.shards, f"host {failed} holds no shard"
        replacement = failed if replacement is None else replacement
        survivors = [h for h in self.group.shards if h != failed]
        providers = choose_providers(self.fleet, survivors, replacement,
                                     self.coder.d, rng=self.rng)
        if block_mb is None:
            block_mb = max(self.group.block_bytes / 1e6, 1e-6)
        t0 = time.perf_counter()
        decision = plan_recovery(self.fleet, self.group.params, replacement,
                                 providers, block_mb=block_mb, scheme=scheme,
                                 rng=self.rng)
        dead_shard = self.group.shards.pop(failed)
        del dead_shard
        report = execute_regeneration(self.group, decision.plan,
                                      decision.overlay, replacement,
                                      providers, rng=self.np_rng)
        if replacement != failed:
            self.hosts = [replacement if h == failed else h
                          for h in self.hosts]
        log = RecoveryLog(decision=decision, report=report,
                          wall_s=time.perf_counter() - t0)
        self.recoveries.append(log)
        return log

    # -- elastic resharding -----------------------------------------------------

    def reshard(self, new_coder: ErasureCoder, new_hosts: Sequence[int],
                ) -> "ECCheckpoint":
        """Elastic scale up/down: reconstruct from any k, re-encode onto a
        new group (possibly different n/k/d and host set)."""
        assert self.group is not None
        buf = self.coder.reconstruct(self.group)
        out = ECCheckpoint(self.fleet, new_coder, new_hosts,
                           seed=self.rng.randint(0, 2 ** 31))
        out.spec = self.spec
        out.group = new_coder.encode(buf, new_hosts)
        out.step = self.step
        return out
