"""Failure -> regeneration plan (the paper's algorithms as the repair engine).

``plan_recovery`` snapshots the available bandwidth between the replacement
host and the d chosen providers, runs the requested scheme(s) and returns
the best plan with its predicted regeneration time.  ``auto`` evaluates
star/FR/TR/FTR and picks the fastest — FTR by construction, but the others
are kept for ablation output.  Straggler mitigation falls out naturally:
a straggler is a low-available-bandwidth provider, so FR shifts traffic off
it and TR/FTR route around it (paper Sections III-V).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

from repro.core import (CodeParams, OverlayNetwork, RepairPlan, plan_fr,
                        plan_ftr, plan_star, plan_tr)
from .topology import Fleet

_PLANNERS = {"star": plan_star, "fr": plan_fr, "tr": plan_tr, "ftr": plan_ftr}


@dataclasses.dataclass
class RecoveryDecision:
    newcomer: int
    providers: List[int]
    overlay: OverlayNetwork
    plan: RepairPlan
    predicted_s: float
    alternatives: Dict[str, float]      # scheme -> predicted time


def choose_providers(fleet: Fleet, survivors: Sequence[int], newcomer: int,
                     d: int, rng: Optional[random.Random] = None,
                     prefer_local: bool = True) -> List[int]:
    """Pick d providers; prefer same-pod hosts (fast tier) when available."""
    rng = rng or fleet.rng
    pool = sorted(survivors)
    if not prefer_local:
        return rng.sample(pool, d)
    local = [h for h in pool if fleet.pod_of(h) == fleet.pod_of(newcomer)]
    remote = [h for h in pool if h not in local]
    rng.shuffle(local)
    rng.shuffle(remote)
    picked = (local + remote)[:d]
    return sorted(picked)


def plan_recovery(fleet: Fleet, params: CodeParams, newcomer: int,
                  providers: Sequence[int], block_mb: float = 1.0,
                  scheme: str = "auto",
                  rng: Optional[random.Random] = None) -> RecoveryDecision:
    overlay = fleet.snapshot_overlay(newcomer, providers, block_mb=block_mb,
                                     rng=rng)
    alts: Dict[str, float] = {}
    best_name, best_plan = None, None
    names = list(_PLANNERS) if scheme == "auto" else [scheme]
    for name in names:
        plan = _PLANNERS[name](overlay, params)
        alts[name] = plan.time
        if best_plan is None or plan.time < best_plan.time:
            best_name, best_plan = name, plan
    return RecoveryDecision(newcomer=newcomer, providers=list(providers),
                            overlay=overlay, plan=best_plan,
                            predicted_s=best_plan.time, alternatives=alts)
