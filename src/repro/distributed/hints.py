"""Activation-sharding hints.

``hint(x, *spec)`` applies ``with_sharding_constraint`` against the ambient
mesh installed by the launcher (``jax.sharding.set_mesh``).  Spec entries
are mesh-axis names (or tuples); axes absent from the ambient mesh are
dropped, and with no ambient mesh the call is a no-op — so model code can
carry production sharding annotations while CPU smoke tests run unchanged.

``BATCH`` expands to ("pod", "data") filtered by the mesh — the canonical
batch sharding of DESIGN.md §7.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

BATCH = ("pod", "data")

SpecEntry = Union[None, str, Tuple[str, ...]]


def _ambient_axes() -> Optional[Tuple[str, ...]]:
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover
        return None
    if am is None or am.empty:
        return None
    return tuple(am.axis_names)


def hint(x, *spec: SpecEntry):
    axes = _ambient_axes()
    if axes is None:
        return x
    def filt(e: SpecEntry):
        if e is None:
            return None
        if isinstance(e, str):
            return e if e in axes else None
        kept = tuple(a for a in e if a in axes)
        return kept if kept else None
    entries = [filt(e) for e in spec]
    # trailing axes of x not mentioned are unconstrained
    return jax.lax.with_sharding_constraint(x, P(*entries))
