"""Sharding rules and distributed-runtime helpers."""
