"""Sharding rules for the production meshes (DESIGN.md §7).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model")
multi-pod.  Policy:

  * batch over ("pod", "data") — pure DP across pods (cheap DCN traffic:
    one grad all-reduce), FSDP+TP inside a pod;
  * params 2-D sharded: the "large input" dim over "data" (FSDP/ZeRO-3 —
    GSPMD inserts the per-layer all-gathers) and the "parallel" dim
    (heads / d_ff / experts / vocab) over "model" (TP/EP);
  * optimizer state shards exactly like its param;
  * KV caches: batch over data when divisible, else sequence over data
    (long-context, batch=1), kv-heads over model.

Rules are name-based over the param-tree paths produced by
``repro.models.init_params``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "tok": ("model", None),
    "unembed": ("model", None),
    "patch_proj": (None, "model"),
    "frame_proj": (None, "model"),
    # attention (stacked leading dim handled by padding with None)
    "wq": ("data", "model", None),
    "wk": ("data", "model", None),
    "wv": ("data", "model", None),
    "wo": ("model", None, "data"),
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    # dense mlp
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # moe — expert parallelism: experts over (pod, model), expert-ffn dim
    # over data; the dense trunk never FSDP-gathers expert tables
    "router": (None, "model"),
    "we_gate": (("pod", "model"), None, "data"),
    "we_up": (("pod", "model"), None, "data"),
    "we_down": (("pod", "model"), "data", None),
    # ssd
    "in_proj": ("data", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "A_log": ("model",),
    "D": ("model",),
    "dt_bias": ("model",),
    "norm_scale": ("model",),
    "out_proj": ("model", "data"),
    # norms
    "scale": (None,),
    "bias": (None,),
}


def _path_names(path) -> list:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            names.append(e.name)
    return names


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def _filter_divisible(mesh: Mesh, spec_entries, shape) -> Tuple:
    """jit argument shardings must divide the dimension exactly (unlike
    internal GSPMD constraints, which pad); drop entries that don't."""
    out = []
    for i, e in enumerate(spec_entries):
        if e is not None and shape[i] % _axis_size(mesh, e) != 0:
            e = None
        out.append(e)
    return tuple(out)


_EXPERT_LEAVES = ("we_gate", "we_up", "we_down")


def param_spec(mesh: Mesh, path, leaf, fsdp: bool = True) -> P:
    names = _path_names(path)
    leafname = names[-1]
    rule = _PARAM_RULES.get(leafname)
    if rule is None:
        return P()
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    rule = tuple(rule)
    if not fsdp and leafname not in _EXPERT_LEAVES:
        # model-parallel-only params (no ZeRO-3 re-gather per microbatch);
        # used when per-device state fits without the data axis
        rule = tuple(None if e == "data" else e for e in rule)
    # drop mesh axes the mesh doesn't have (e.g. "pod" on single-pod)
    avail = set(mesh.axis_names)
    def keep(e):
        if e is None or isinstance(e, str):
            return e if (e is None or e in avail) else None
        kept = tuple(a for a in e if a in avail)
        return kept if kept else None
    rule = tuple(keep(e) for e in rule)
    # stacked containers ('blocks', 'shared') prepend a layer axis
    if ndim == len(rule) + 1:
        rule = (None,) + rule
    elif ndim != len(rule):
        # unexpected rank (e.g. scalar): replicate
        return P()
    return P(*_filter_divisible(mesh, rule, leaf.shape))


def param_shardings(mesh: Mesh, params_tree: Any, fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf,
                                                          fsdp=fsdp)),
        params_tree)


# ---------------------------------------------------------------------------
# batch / cache / activation specs
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree: Any) -> Any:
    ba = batch_axes(mesh)

    def spec(path, leaf):
        entries = _filter_divisible(mesh, (ba,) + (None,) * (leaf.ndim - 1),
                                    leaf.shape)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_tree: Any,
                    batch_size: int) -> Any:
    ba = batch_axes(mesh)
    shard_batch = batch_size % data_size(mesh) == 0
    kv_div = cfg.num_kv_heads % mesh.shape["model"] == 0

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v", "shared_k", "shared_v"):
            # (L|napp, B, S, KV, hd); when KV doesn't divide the model axis
            # shard head_dim instead (divisible for every assigned arch)
            kv_e, hd_e = ("model", None) if kv_div else (None, "model")
            if shard_batch:
                entries = (None, ba, None, kv_e, hd_e)
            else:
                entries = (None, None, "data", kv_e, hd_e)
        elif name == "conv":    # (L, B, conv-1, C)
            entries = (None, ba if shard_batch else None, None, "model")
        elif name == "state":   # (L, B, Hs, P, N)
            entries = (None, ba if shard_batch else None, "model", None, None)
        else:
            entries = (None,) * leaf.ndim
        return NamedSharding(mesh, P(*_filter_divisible(mesh, entries,
                                                        leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def activation_spec(mesh: Mesh) -> P:
    """(B, S, d) hidden-state constraint."""
    return P(batch_axes(mesh), None, None)
