"""Analyses over a flight-recorder JSONL trace.

Loads the event log ``FlightRecorder.to_jsonl`` wrote and answers the
questions the end-of-run scalars cannot:

* :func:`top_bottleneck_links` — which directed links carried the most
  contention (user-seconds) and how saturated they ran;
* :func:`watchdog_funnel` — the mitigation ladder as a funnel: flags ->
  rescue replans -> straggler evictions -> give-ups;
* :func:`plan_error_attribution` — which bottleneck links the late
  repairs (realized >> predicted ETA) completed on, with the excess
  seconds attributed per link;
* :func:`node_brownout_timeline` — per-node degrade episodes and total
  degraded time;
* :func:`top_links_by_bytes` — which links moved the most data-plane
  bytes (coded repair blocks + degraded-read fragments, ISSUE 10).

Run as a module for a text report::

    python -m repro.obs.report trace.jsonl [--top 10]

All analyses are defensive about the ring buffer: per-link aggregates
prefer the exact integrals the simulator stored in the header
(``meta.links``, accumulated online by ``LinkUsageTracer``) and fall
back to reconstructing from ``link_users`` events only when absent.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple


def load_jsonl(path: str) -> Tuple[dict, List[dict]]:
    """Read a flight-recorder JSONL file -> (header, events)."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty trace file")
    header = json.loads(lines[0])
    if header.get("kind") != "repro.fleet.trace":
        raise ValueError(f"{path}: not a flight-recorder trace "
                         f"(kind={header.get('kind')!r})")
    return header, [json.loads(ln) for ln in lines[1:]]


def _derive_link_stats(events: List[dict], t_end: float) -> dict:
    """Reconstruct per-link aggregates from ``link_users`` events (the
    fallback when the header carries no ``meta.links`` snapshot)."""
    users: Dict[str, int] = {}
    since: Dict[str, float] = {}
    out: Dict[str, dict] = {}

    def integrate(key: str, t: float) -> None:
        prev = users.get(key, 0)
        if prev > 0:
            dt = t - since[key]
            if dt > 0:
                cell = out.setdefault(key, {"busy_time": 0.0,
                                            "user_seconds": 0.0,
                                            "max_users": 0})
                cell["busy_time"] += dt
                cell["user_seconds"] += prev * dt

    for e in events:
        if e["ev"] != "link_users":
            continue
        key = f"{e['src']}->{e['dst']}"
        integrate(key, e["t"])
        if e["users"] > 0:
            users[key] = e["users"]
            since[key] = e["t"]
            cell = out.setdefault(key, {"busy_time": 0.0,
                                        "user_seconds": 0.0,
                                        "max_users": 0})
            cell["max_users"] = max(cell["max_users"], e["users"])
        else:
            users.pop(key, None)
            since.pop(key, None)
    for key in list(users):
        integrate(key, t_end)
    return out


def link_stats(header: dict, events: List[dict]) -> dict:
    """Per-link ``{"src->dst": {busy_time, user_seconds, max_users}}``."""
    meta = header.get("meta", {})
    snap = meta.get("links")
    if snap and snap.get("links"):
        return snap["links"]
    t_end = meta.get("duration") or max((e["t"] for e in events),
                                        default=0.0)
    return _derive_link_stats(events, t_end)


def top_bottleneck_links(header: dict, events: List[dict],
                         k: int = 10) -> List[Tuple[str, dict]]:
    """The ``k`` links with the most user-seconds (contention), sorted."""
    stats = link_stats(header, events)
    return sorted(stats.items(),
                  key=lambda kv: (-kv[1]["user_seconds"], kv[0]))[:k]


def link_bytes(header: dict, events: List[dict]) -> dict:
    """Per-link data-plane wire bytes:
    ``{"src->dst": {"repair_bytes": x, "read_bytes": y}}``.

    Prefers the exact ledger the simulator stored in the header
    (``meta.dataplane.links``, written by ``DataPlane.snapshot``); falls
    back to summing ``repair_block`` events when the snapshot is absent
    (read bytes cannot be reconstructed that way — ``read_complete``
    carries a total, not per-link splits — so the fallback reports
    repair bytes only).  Empty dict when the run had no data plane.
    """
    meta = header.get("meta", {})
    snap = meta.get("dataplane")
    if snap and snap.get("links"):
        return snap["links"]
    out: Dict[str, dict] = {}
    for e in events:
        if e["ev"] != "repair_block":
            continue
        key = f"{e['producer']}->{e['dst']}"
        cell = out.setdefault(key, {"repair_bytes": 0.0, "read_bytes": 0.0})
        cell["repair_bytes"] += e["bytes"]
    return out


def top_links_by_bytes(header: dict, events: List[dict],
                       k: int = 10) -> List[Tuple[str, dict]]:
    """The ``k`` links that moved the most data-plane bytes (repair +
    read), sorted heaviest first, name-tiebroken."""
    stats = link_bytes(header, events)
    return sorted(
        stats.items(),
        key=lambda kv: (-(kv[1].get("repair_bytes", 0.0)
                          + kv[1].get("read_bytes", 0.0)), kv[0]))[:k]


def watchdog_funnel(events: List[dict]) -> dict:
    """The mitigation ladder as a funnel of event counts."""
    return {
        "flags": sum(1 for e in events if e["ev"] == "watchdog_flag"),
        "replans": sum(1 for e in events if e["ev"] == "repair_replan"
                       and e.get("kind") == "watchdog"),
        "evictions": sum(1 for e in events if e["ev"] == "repair_evicted"),
        "giveups": sum(1 for e in events if e["ev"] == "watchdog_giveup"),
    }


def plan_error_attribution(events: List[dict],
                           k: int = 10) -> List[dict]:
    """Attribute realized-vs-predicted ETA error to bottleneck links.

    Groups ``repair_complete`` events (those with a finite prediction) by
    the bottleneck link they finished on; per link, sums the excess
    seconds (realized - predicted, clamped at 0) and averages the
    relative plan error.  Sorted by excess, worst first.
    """
    groups: Dict[str, dict] = {}
    for e in events:
        if e["ev"] != "repair_complete" or e.get("plan_err") is None:
            continue
        bn = e.get("bottleneck")
        key = f"{bn[0]}->{bn[1]}" if bn else "(none)"
        cell = groups.setdefault(key, {"link": key, "repairs": 0,
                                       "excess_seconds": 0.0,
                                       "err_sum": 0.0})
        cell["repairs"] += 1
        cell["excess_seconds"] += max(0.0, e["realized"] - e["predicted"])
        cell["err_sum"] += e["plan_err"]
    out = []
    for cell in groups.values():
        cell["mean_plan_err"] = cell.pop("err_sum") / cell["repairs"]
        out.append(cell)
    out.sort(key=lambda c: (-c["excess_seconds"], c["link"]))
    return out[:k]


def node_brownout_timeline(events: List[dict],
                           t_end: Optional[float] = None) -> dict:
    """Per-node brownout episodes ``[start, factor, end-or-None]`` plus
    total degraded seconds (open episodes count up to ``t_end``)."""
    if t_end is None:
        t_end = max((e["t"] for e in events), default=0.0)
    nodes: Dict[int, dict] = {}

    def close(node: int, t: float) -> None:
        cell = nodes.get(node)
        if cell and cell["episodes"] and cell["episodes"][-1][2] is None:
            ep = cell["episodes"][-1]
            ep[2] = t
            cell["degraded_time"] += t - ep[0]

    for e in events:
        if e["ev"] == "node_degrade":
            close(e["node"], e["t"])        # re-degrade supersedes
            cell = nodes.setdefault(e["node"], {"episodes": [],
                                                "degraded_time": 0.0})
            cell["episodes"].append([e["t"], e["factor"], None])
        elif e["ev"] == "node_recover":
            close(e["node"], e["t"])
    for node in nodes:
        close(node, t_end)
    return nodes


def render_report(header: dict, events: List[dict], top: int = 10) -> str:
    """Human-readable text report over one trace."""
    meta = header.get("meta", {})
    lines = [
        f"flight recorder: {header.get('events', len(events))} events "
        f"({header.get('dropped', 0)} dropped), "
        f"seed={meta.get('seed')}, config={meta.get('config', '?')}",
        "",
        f"top {top} bottleneck links (user-seconds of contention):",
    ]
    for key, st in top_bottleneck_links(header, events, top):
        lines.append(f"  {key:>10}  busy {st['busy_time']:10.1f}s  "
                     f"user-s {st['user_seconds']:10.1f}  "
                     f"peak users {st['max_users']}")
    dp_bytes = top_links_by_bytes(header, events, top)
    if dp_bytes:
        lines += ["", f"top {min(top, len(dp_bytes))} links by data-plane "
                  "bytes (repair + read):"]
        for key, st in dp_bytes:
            rb = st.get("repair_bytes", 0.0)
            db = st.get("read_bytes", 0.0)
            lines.append(f"  {key:>10}  repair {rb / 1e9:10.3f} GB  "
                         f"read {db / 1e9:10.3f} GB")
    funnel = watchdog_funnel(events)
    lines += ["", "watchdog funnel: "
              f"{funnel['flags']} flagged -> {funnel['replans']} replanned "
              f"-> {funnel['evictions']} evicted -> "
              f"{funnel['giveups']} given up"]
    attribution = plan_error_attribution(events, top)
    if attribution:
        lines += ["", "plan-error attribution (late repairs by "
                  "bottleneck link):"]
        for cell in attribution:
            lines.append(f"  {cell['link']:>10}  {cell['repairs']:4d} "
                         f"repairs  excess {cell['excess_seconds']:9.1f}s  "
                         f"mean err {cell['mean_plan_err']:+.2f}")
    brown = node_brownout_timeline(events, meta.get("duration"))
    if brown:
        lines += ["", "node brownouts:"]
        for node in sorted(brown):
            cell = brown[node]
            lines.append(f"  node {node:3d}  {len(cell['episodes'])} "
                         f"episodes  degraded {cell['degraded_time']:.1f}s")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a text report from a flight-recorder JSONL "
                    "trace")
    ap.add_argument("trace", help="path to a .jsonl trace file")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per ranking (default 10)")
    args = ap.parse_args(argv)
    header, events = load_jsonl(args.trace)
    print(render_report(header, events, args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
