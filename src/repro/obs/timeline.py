"""Link occupancy timelines, integrated online.

``LinkShareModel`` (``fleet/sharing.py``) calls :meth:`LinkUsageTracer.
on_users` on every per-link occupancy change (acquire/release of repairs
and degraded reads).  The tracer integrates, per directed physical link:

* ``busy_time`` — seconds with >= 1 occupant;
* ``user_seconds`` — the time integral of the occupant count (two flows
  for 5 s contribute 10), the contention measure;
* ``max_users`` — the peak occupant count.

These aggregates are exact regardless of the flight recorder's ring
buffer (they are accumulated here, not reconstructed from events), which
is what makes the conservation check in ``benchmarks/check_trace.py``
valid on long runs: every active repair occupies at least one link for
its whole active window, so ``total user-seconds >= sum of realized
regeneration times``.

When a :class:`~repro.obs.trace.FlightRecorder` is attached, every
change is also emitted as a ``link_users`` event — the Chrome export
renders those as per-link counter tracks.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from .trace import FlightRecorder

Link = Tuple[int, int]


class LinkUsageTracer:
    """Online per-link utilization/contention integrator.

    ``clock`` returns the current simulated time (the simulator passes
    ``lambda: self.now``); ``recorder`` optionally mirrors every change
    into the flight recorder.
    """

    def __init__(self, clock: Callable[[], float],
                 recorder: Optional[FlightRecorder] = None):
        self.clock = clock
        self.recorder = recorder
        self.busy_time: Dict[Link, float] = {}
        self.user_seconds: Dict[Link, float] = {}
        self.max_users: Dict[Link, int] = {}
        self._users: Dict[Link, int] = {}
        self._since: Dict[Link, float] = {}

    def _integrate(self, link: Link, t: float) -> None:
        prev = self._users.get(link, 0)
        if prev > 0:
            dt = t - self._since[link]
            if dt > 0:
                self.busy_time[link] = self.busy_time.get(link, 0.0) + dt
                self.user_seconds[link] = (self.user_seconds.get(link, 0.0)
                                           + prev * dt)

    def on_users(self, link: Link, users: int) -> None:
        """The occupant count of ``link`` just changed to ``users``."""
        t = float(self.clock())
        self._integrate(link, t)
        if users > 0:
            self._users[link] = users
            self._since[link] = t
            if users > self.max_users.get(link, 0):
                self.max_users[link] = users
        else:
            self._users.pop(link, None)
            self._since.pop(link, None)
        if self.recorder is not None:
            self.recorder.emit(t, "link_users", src=link[0], dst=link[1],
                               users=users)

    def finish(self, t_end: float) -> None:
        """Close the books at ``t_end``: integrate every still-occupied
        link up to the horizon (idempotent — a second call adds zero)."""
        for link in list(self._users):
            self._integrate(link, t_end)
            self._since[link] = t_end

    def snapshot(self) -> dict:
        """JSON-ready aggregate view (stringified ``"src->dst"`` keys)."""
        links = {}
        for link in sorted(set(self.busy_time) | set(self.max_users)):
            links[f"{link[0]}->{link[1]}"] = {
                "busy_time": self.busy_time.get(link, 0.0),
                "user_seconds": self.user_seconds.get(link, 0.0),
                "max_users": self.max_users.get(link, 0),
            }
        return {
            "links": links,
            "total_busy_time": sum(self.busy_time.values()),
            "total_user_seconds": sum(self.user_seconds.values()),
        }
