"""Flight recorder: a bounded ring buffer of simulation events.

The fleet simulator's metrics collapse a whole run into ~30 end-of-run
scalars; debugging the paper's central claim — regeneration *time* under
heterogeneous links — needs timelines: which link was the bottleneck,
when a tree bypassed it, why a repair missed its promised ETA.  The
:class:`FlightRecorder` is the storage layer for those timelines: the
simulator ``emit()``\\ s one flat dict per lifecycle event (see
``fleet/sim.py`` for the vocabulary) into a ``deque(maxlen=capacity)``,
so a runaway run overwrites its oldest events instead of exhausting
memory (``dropped`` counts the overwritten ones).

Two export formats:

* **JSONL** (:meth:`FlightRecorder.to_jsonl`): a header line carrying
  ``schema_version`` / ``kind`` / run metadata, then one strict-JSON
  object per event — the machine-readable log ``repro.obs.report``
  analyzes.
* **Chrome trace-event JSON** (:meth:`FlightRecorder.to_chrome`):
  repair lifecycles as async span pairs (``queued`` then ``transfer``,
  keyed by the repair id), node down/brownout spans, link occupancy as
  counter tracks, and everything else as instants.  Load the file in
  Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Everything written out passes through :func:`json_sanitize`, which maps
non-finite floats to ``null`` and numpy scalars to Python ones — the
exports (like the bench JSON files since ISSUE 7) parse under strict
JSON tooling, no ``Infinity`` literals.

Timestamps: events carry simulated seconds; the Chrome export scales to
microseconds (the format's unit), so one simulated second reads as 1 ms
at Perfetto's default zoom.
"""
from __future__ import annotations

import collections
import json
import math
import warnings
from typing import Any, Dict, Iterable, List, Optional

SCHEMA_VERSION = 1
TRACE_KIND = "repro.fleet.trace"

_US = 1e6                         # simulated seconds -> trace microseconds

# Chrome trace "processes" grouping the tracks
_PID_REPAIRS, _PID_NODES, _PID_LINKS, _PID_READS = 1, 2, 3, 4


def json_sanitize(obj: Any) -> Any:
    """Recursively make ``obj`` strict-JSON-safe.

    Non-finite floats become ``None`` (strict JSON has no ``Infinity`` /
    ``NaN`` literals), numpy scalars collapse to Python scalars, tuples
    become lists, and dict keys are stringified when not already str.
    """
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, dict):
        return {k if isinstance(k, str) else str(k): json_sanitize(v)
                for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    item = getattr(obj, "item", None)      # numpy scalars
    if callable(item):
        return json_sanitize(item())
    return obj


class FlightRecorder:
    """Bounded in-memory event log owned by a :class:`FleetSimulator`.

    ``capacity`` bounds memory: past it the oldest events are overwritten
    and ``dropped`` counts how many.  ``meta`` is free-form run metadata
    (seed, scenario, end-of-run summary) carried into every export header.
    """

    def __init__(self, capacity: int = 1 << 16,
                 meta: Optional[Dict[str, Any]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.dropped = 0
        self.meta: Dict[str, Any] = dict(meta or {})
        self._events: collections.deque = collections.deque(maxlen=capacity)

    def emit(self, t: float, ev: str, **attrs: Any) -> None:
        """Record one event at simulated time ``t`` seconds."""
        if len(self._events) == self.capacity:
            if self.dropped == 0:
                # warn once at the first wrap: from here the timeline is a
                # suffix, so a consumer replaying "the whole run" should
                # know the head is gone (the header still counts exactly
                # how many events fell off)
                warnings.warn(
                    f"FlightRecorder ring buffer wrapped at capacity "
                    f"{self.capacity}; oldest events are being dropped "
                    f"(see the 'dropped_events' header field)",
                    RuntimeWarning, stacklevel=2)
            self.dropped += 1
        e = {"t": float(t), "ev": ev}
        e.update(attrs)
        self._events.append(e)

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def header(self) -> dict:
        """The export header: schema version, metadata, drop accounting."""
        return json_sanitize({
            "schema_version": SCHEMA_VERSION,
            "kind": TRACE_KIND,
            "capacity": self.capacity,
            "dropped": self.dropped,
            # explicit alias: "dropped" reads ambiguously (dropped what?);
            # consumers should prefer this key, the old one stays for
            # check_trace.py and any external reader already shipped
            "dropped_events": self.dropped,
            "events": len(self._events),
            "meta": self.meta,
        })

    # -- JSONL ------------------------------------------------------------

    def to_jsonl(self) -> str:
        """Header line + one strict-JSON event per line."""
        lines = [json.dumps(self.header(), sort_keys=True,
                            allow_nan=False)]
        for e in self._events:
            lines.append(json.dumps(json_sanitize(e), allow_nan=False))
        return "\n".join(lines) + "\n"

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    # -- Chrome trace-event JSON ------------------------------------------

    def to_chrome(self) -> dict:
        return chrome_trace(self.events, header=self.header())

    def save_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, allow_nan=False)


def chrome_trace(events: Iterable[dict],
                 header: Optional[dict] = None) -> dict:
    """Derive a Chrome trace-event object from flight-recorder events.

    Span derivation (async ``b``/``e`` pairs, matched by category + id):

    * ``repair_queued`` opens a ``queued`` span (cat ``repair_wait``, id =
      rid); ``repair_admitted`` closes it and opens the ``transfer`` span
      (cat ``repair``).  ``repair_complete`` / ``repair_abort`` /
      ``repair_evicted`` close ``transfer`` with ``args.reason`` set to
      ``complete`` / ``abort`` / ``evict`` — so the number of ``e``
      events named ``transfer`` with reason in {complete, abort} equals
      the metrics' ``completed + aborted``.
    * ``node_fail`` .. ``node_repaired`` become ``down`` spans and
      ``node_degrade`` .. ``node_recover`` become ``brownout`` spans on
      the nodes process (a re-degrade supersedes: the open span closes).
    * ``read_queued`` .. ``read_complete`` / ``read_abort`` become
      ``read`` spans (cat ``read``) on the reads process — a category
      distinct from ``repair`` so repair-transfer span counting is
      untouched by the data plane.
    * ``link_users`` becomes a per-link counter track (occupancy over
      time); everything else (including ``read_drop`` and
      ``repair_block``) is an instant event.

    Spans still open when the log ends (or whose begin was overwritten by
    the ring buffer) are closed at the last timestamp with
    ``args.unfinished: true`` / silently ignored respectively, so the
    output always loads.
    """
    te: List[dict] = []
    for pid, pname in ((_PID_REPAIRS, "repairs"), (_PID_NODES, "nodes"),
                       (_PID_LINKS, "links"), (_PID_READS, "reads")):
        te.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                   "ts": 0, "args": {"name": pname}})

    open_spans: Dict[tuple, tuple] = {}   # key -> (name, cat, id, pid, tid)
    last_ts = 0.0

    def begin(key: tuple, name: str, cat: str, ident: Any, pid: int,
              tid: int, ts: float, args: dict) -> None:
        te.append({"ph": "b", "cat": cat, "id": ident, "name": name,
                   "pid": pid, "tid": tid, "ts": ts, "args": args})
        open_spans[key] = (name, cat, ident, pid, tid)

    def end(key: tuple, ts: float, args: dict) -> None:
        info = open_spans.pop(key, None)
        if info is None:        # begin fell off the ring buffer
            return
        name, cat, ident, pid, tid = info
        te.append({"ph": "e", "cat": cat, "id": ident, "name": name,
                   "pid": pid, "tid": tid, "ts": ts, "args": args})

    def instant(name: str, ts: float, tid: int, args: dict,
                pid: int = _PID_REPAIRS, scope: str = "t") -> None:
        te.append({"ph": "i", "name": name, "pid": pid, "tid": tid,
                   "ts": ts, "s": scope, "args": args})

    for e in events:
        ts = e["t"] * _US
        last_ts = max(last_ts, ts)
        ev = e["ev"]
        args = {k: v for k, v in e.items() if k not in ("t", "ev")}
        rid = e.get("rid")
        node = e.get("node", 0)
        if ev == "repair_queued":
            begin(("q", rid), "queued", "repair_wait", rid, _PID_REPAIRS,
                  node, ts, args)
        elif ev == "repair_admitted":
            end(("q", rid), ts, {})
            begin(("x", rid), "transfer", "repair", rid, _PID_REPAIRS,
                  node, ts, args)
        elif ev == "repair_complete":
            end(("x", rid), ts, dict(args, reason="complete"))
        elif ev == "repair_abort":
            end(("x", rid), ts, dict(args, reason="abort"))
        elif ev == "repair_evicted":
            end(("x", rid), ts, dict(args, reason="evict"))
        elif ev == "node_fail":
            begin(("down", node), "down", "node_down", node, _PID_NODES,
                  node, ts, args)
        elif ev == "node_repaired":
            end(("down", node), ts, args)
        elif ev == "node_degrade":
            end(("brownout", node), ts, {"superseded": True})
            begin(("brownout", node), "brownout", "node_brownout", node,
                  _PID_NODES, node, ts, args)
        elif ev == "node_recover":
            end(("brownout", node), ts, args)
        elif ev == "read_queued":
            # data-plane reads (ISSUE 10): span per read on the reads
            # process, cat "read" — deliberately NOT "repair" so
            # finished-transfer counting stays a pure repair invariant
            begin(("r", e.get("rdid")), "read", "read", e.get("rdid"),
                  _PID_READS, e.get("dst", 0), ts, args)
        elif ev == "read_complete":
            end(("r", e.get("rdid")), ts, dict(args, reason="complete"))
        elif ev == "read_abort":
            end(("r", e.get("rdid")), ts, dict(args, reason="abort"))
        elif ev == "link_users":
            te.append({"ph": "C", "name": f"link {e['src']}->{e['dst']}",
                       "pid": _PID_LINKS, "tid": 0, "ts": ts,
                       "args": {"users": e["users"]}})
        elif ev in ("data_loss", "capacity_shock", "estimate_refresh"):
            instant(ev, ts, 0, args, scope="g")
        else:   # repair_deferred, repair_replan, watchdog_*, future events
            instant(ev, ts, node, args)

    for key in sorted(open_spans, key=str):
        end(key, last_ts, {"unfinished": True})

    return json_sanitize({
        "traceEvents": te,
        "displayTimeUnit": "ms",
        "otherData": header or {},
    })


def finished_transfer_spans(trace: dict,
                            reasons: tuple = ("complete", "abort"),
                            ) -> int:
    """Count closed transfer spans by reason in a Chrome trace object.

    With the default reasons this is the span count the acceptance check
    compares against ``completed + aborted``.
    """
    return sum(1 for e in trace.get("traceEvents", ())
               if e.get("ph") == "e" and e.get("name") == "transfer"
               and e.get("args", {}).get("reason") in reasons)
