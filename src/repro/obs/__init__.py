"""Observability: flight recorder, link/node timelines, planner profiling.

The subsystem is strictly opt-in and zero-overhead when off: the fleet
simulator only allocates a :class:`FlightRecorder` when
``Scenario.trace`` is set, the planning core only calls into a
:class:`PlannerProfile` when one is passed as ``plan(..., profile=)``,
and neither path touches any rng stream — tracing is observation, not
perturbation (the goldens pin this bitwise).

See ``src/README.md`` ("Observability") for the trace format, the
Perfetto how-to, and the profiling hook contract; ``repro.obs.report``
is the analysis CLI.
"""
from .profile import PlannerProfile
from .timeline import LinkUsageTracer
from .trace import (FlightRecorder, SCHEMA_VERSION, TRACE_KIND,
                    chrome_trace, finished_transfer_spans, json_sanitize)

__all__ = [
    "FlightRecorder", "LinkUsageTracer", "PlannerProfile",
    "SCHEMA_VERSION", "TRACE_KIND", "chrome_trace",
    "finished_transfer_spans", "json_sanitize",
]
