"""Planner profiling: per-stage wall time for ``plan()`` / ``plan_many()``.

A :class:`PlannerProfile` is the ``profile=`` hook the unified planner
API accepts (``repro.core.api``): the dispatcher wraps the whole planner
call in a ``total`` stage and records call-shape metadata (scheme,
resolved engine, batch size, fallback taken); planners registered with
``accepts_profile`` — fr and ftr — additionally time their internal
stages (closed form, bisection, candidate generation, local search,
final solve, witness extraction) and count work items (lanes, candidate
trees, bisection iterations).

The contract is duck-typed on purpose: the planning core never imports
this module — it calls ``profile.stage(name)`` (a context manager),
``profile.count(name, n)`` and ``profile.note(**kw)`` on whatever object
the caller passed, and skips all of it when ``profile is None`` (the
zero-overhead default).  ``summary()`` renders the accumulated numbers
as the JSON-ready dict ``benchmarks/run.py`` publishes as the
``profile`` section of ``BENCH_planning.json`` — the measured per-stage
baseline the ROADMAP-item-2 JAX port is judged against.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, Iterator, List


class PlannerProfile:
    """Accumulates per-stage wall time, counters and call metadata.

    Reusable across calls: a second ``plan_many`` with the same profile
    adds to the same stages (mean-of-N timing).  Not thread-safe.
    """

    def __init__(self) -> None:
        # stage name -> [calls, total seconds], in first-seen order
        self._stages: Dict[str, List[float]] = {}
        self.counters: Dict[str, int] = {}
        self.meta: Dict[str, Any] = {}

    @contextlib.contextmanager
    def stage(self, name: str) -> Iterator[None]:
        """Time one stage; nests and repeats accumulate."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def add(self, name: str, seconds: float, calls: int = 1) -> None:
        cell = self._stages.setdefault(name, [0, 0.0])
        cell[0] += calls
        cell[1] += seconds

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + int(n)

    def note(self, **kw: Any) -> None:
        """Attach call-shape metadata (last write wins per key)."""
        self.meta.update(kw)

    def summary(self) -> dict:
        """JSON-ready view: stages (calls + milliseconds), counters, meta."""
        return {
            "stages": {name: {"calls": int(calls), "ms": sec * 1e3}
                       for name, (calls, sec) in self._stages.items()},
            "counters": dict(self.counters),
            "meta": dict(self.meta),
        }
