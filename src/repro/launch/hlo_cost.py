"""Trip-count-aware cost analysis over post-optimization HLO text.

``compiled.cost_analysis()`` visits every computation once — a
``lax.scan``'s while body is counted a single time no matter its trip
count, which silently undercounts scanned-layer models by ~L.  This module
re-derives FLOPs / HBM bytes / collective link-bytes by walking the HLO
call graph and multiplying while-loop bodies by their
``known_trip_count`` (emitted by XLA after loop analysis).

Model:
  * FLOPs: dot ops only (2 * prod(output) * prod(lhs contracting dims)) —
    matmul-dominated workloads; elementwise flops are ignored (they are
    bandwidth, not compute);
  * HBM bytes: per *top-level* op in each computation: unique operand bytes
    + output bytes, skipping pure-metadata ops (parameter/constant/tuple/
    get-tuple-element/bitcast) and control ops (while/conditional/call whose
    bodies are traversed instead).  Fusion internals are not counted — the
    fusion call site's operands/outputs are the actual HBM traffic;
  * collectives: ring-model link bytes per op (see hlo_analysis), scaled by
    the enclosing trip counts.

Validated against compiled.cost_analysis() on scan-free probes in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import _COLL_OPS, _DTYPE_BYTES, _SHAPE_RE, _group_size

_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[a-z0-9].*?\)?)\s+([a-z0-9\-]+)\((.*)$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_ATTR_RE = re.compile(
    r"(?:body|to_apply|calls|true_computation|false_computation|branch_computations)=")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call",
               "after-all", "partition-id", "replica-id", "iota"}
_CONTROL = {"while", "conditional", "call", "fusion"}


def _parse_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dtype, dims in _parse_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_args(argstr: str) -> List[str]:
    """Top-level comma split of 'op(...)' argument text (trailing attrs cut
    by the caller)."""
    args, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
        elif ch == "," and depth == 0:
            args.append("".join(cur).strip())
            cur = []
            continue
        cur.append(ch)
    if cur:
        args.append("".join(cur).strip())
    return [a for a in args if a]


@dataclasses.dataclass
class Metrics:
    flops: float = 0.0
    bytes: float = 0.0
    coll_link_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLL_OPS})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLL_OPS})

    def add(self, other: "Metrics", scale: float = 1.0,
            include_bytes: bool = True) -> None:
        self.flops += other.flops * scale
        if include_bytes:
            self.bytes += other.bytes * scale
        for k in self.coll_link_bytes:
            self.coll_link_bytes[k] += other.coll_link_bytes[k] * scale
            self.coll_counts[k] += other.coll_counts[k] * scale

    @property
    def total_link_bytes(self) -> float:
        return sum(self.coll_link_bytes.values())


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the '(' of the op


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: List[_Op] = []
        self.shapes: Dict[str, str] = {}


def _parse_module(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line) and ("=" not in line.split("(")[0]):
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if line.startswith("}"):
            # keep cur for trailing attrs safety; reset
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            cur.ops.append(_Op(name, type_str, opcode, rest))
            cur.shapes[name] = type_str
    return comps


def _called_comps(op: _Op) -> List[str]:
    """Names of computations invoked by this op (body/calls/branches)."""
    names = []
    for attr in ("body", "to_apply", "calls", "true_computation",
                 "false_computation"):
        m = re.search(attr + r"=%?([\w.\-]+)", op.rest)
        if m:
            names.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for n in m.group(1).split(","):
            names.append(n.strip().lstrip("%"))
    return names


def _dot_flops(comp: _Computation, op: _Op) -> float:
    out_dims = _parse_dims(op.type_str)
    if not out_dims:
        return 0.0
    out_n = 1
    for d in out_dims[0][1]:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.rest)
    if not m:
        return 2.0 * out_n  # dot with no contraction (outer product-ish)
    idxs = [int(x) for x in m.group(1).split(",") if x.strip()]
    args = _split_args(op.rest)
    lhs_name = args[0].lstrip("%") if args else None
    lhs_type = comp.shapes.get(lhs_name, "")
    lhs_dims = _parse_dims(lhs_type)
    if not lhs_dims:
        return 2.0 * out_n
    contract = 1
    for i in idxs:
        if i < len(lhs_dims[0][1]):
            contract *= lhs_dims[0][1][i]
    return 2.0 * out_n * contract


def _conv_flops(comp: _Computation, op: _Op) -> float:
    out_dims = _parse_dims(op.type_str)
    if not out_dims:
        return 0.0
    out_n = 1
    for d in out_dims[0][1]:
        out_n *= d
    args = _split_args(op.rest)
    if len(args) < 2:
        return 2.0 * out_n
    ker = _parse_dims(comp.shapes.get(args[1].lstrip("%"), ""))
    kn = 1
    if ker:
        for d in ker[0][1]:
            kn *= d
    # approximate: 2 * out * kernel_elems / out_features
    of = out_dims[0][1][-1] if out_dims[0][1] else 1
    return 2.0 * out_n * max(kn // max(of, 1), 1)


def analyze_report(text: str, top: int = 12) -> str:
    """Debug view: top flop-contributing computations (with multiplicity)."""
    comps = _parse_module(text)
    # count effective trips per computation by walking from entry
    trips: Dict[str, float] = {}
    entry = None
    for raw in text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(raw.strip())
            if m:
                entry = m.group(1)
    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        trips[name] = trips.get(name, 0.0) + mult
        for op in comp.ops:
            if op.opcode in _CONTROL:
                scale = 1.0
                if op.opcode == "while":
                    tm = _TRIP_RE.search(op.rest)
                    scale = float(tm.group(1)) if tm else 1.0
                for c in _called_comps(op):
                    if op.opcode == "while" and "cond" in c:
                        continue
                    walk(c, mult * scale)
    walk(entry, 1.0)
    rows = []
    for name, mult in trips.items():
        comp = comps[name]
        fl = sum(_dot_flops(comp, op) for op in comp.ops if op.opcode == "dot")
        if fl > 0:
            rows.append((fl * mult, fl, mult, name))
    rows.sort(reverse=True)
    out = ["flops_total  flops_once  trips  computation"]
    for tot, fl, mult, name in rows[:top]:
        out.append(f"{tot:12.3e} {fl:11.3e} {mult:6.0f}  {name[:80]}")
    return "\n".join(out)


def analyze_report_bytes(text: str, top: int = 15) -> str:
    """Debug view: top HBM-byte and collective contributors per computation."""
    comps = _parse_module(text)
    trips: Dict[str, float] = {}
    entry = None
    for raw in text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(raw.strip())
            if m:
                entry = m.group(1)

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        trips[name] = trips.get(name, 0.0) + mult
        for op in comp.ops:
            if op.opcode in ("while", "conditional", "call"):
                scale = 1.0
                if op.opcode == "while":
                    tm = _TRIP_RE.search(op.rest)
                    scale = float(tm.group(1)) if tm else 1.0
                for c in _called_comps(op):
                    if op.opcode == "while" and "cond" in c:
                        continue
                    walk(c, mult * scale)
    walk(entry, 1.0)

    def comp_bytes(comp: _Computation) -> Tuple[float, float, List[str]]:
        b, cl = 0.0, 0.0
        coll_lines: List[str] = []
        for op in comp.ops:
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in _COLL_OPS and not oc.endswith("-done"):
                size = _bytes_of(op.type_str)
                n = _group_size(op.rest, 1)
                frac = (n - 1) / n if n > 1 else 0.0
                lb = {"all-reduce": 2 * frac * size,
                      "reduce-scatter": frac * size * n,
                      "collective-permute": float(size)}.get(base, frac * size)
                cl += lb
                coll_lines.append(f"{base} {op.type_str[:42]} grp={n} "
                                  f"link={lb:.2e}")
                continue
            if oc in _SKIP_BYTES:
                continue
            b += _op_bytes(comp, op)
        return b, cl, coll_lines

    rows = []
    for name, mult in trips.items():
        b, cl, lines = comp_bytes(comps[name])
        if b * mult > 0 or cl * mult > 0:
            rows.append((b * mult + cl * mult, b * mult, cl * mult, mult,
                         name, lines))
    rows.sort(reverse=True)
    out = ["bytes_total  coll_total  trips  computation"]
    for tot, b, cl, mult, name, lines in rows[:top]:
        out.append(f"{b:11.3e} {cl:11.3e} {mult:6.0f}  {name[:70]}")
        for l in lines[:4]:
            out.append(f"      {l}")
    return "\n".join(out)


# ops that move only their OUTPUT-sized region (slicing/addressing reads a
# window of the operand, not the whole buffer)
_OUTPUT_ONLY = {"dynamic-slice", "gather", "slice", "reshape", "broadcast",
                "pad", "reverse", "reduce", "reduce-window"}


def _op_bytes(comp: "_Computation", op: "_Op") -> float:
    """HBM traffic model per top-level op.

    Default: output + unique operands.  Slicing ops move only the sliced
    window (= output); dynamic-update-slice / scatter move ~2x the update
    region (read-modify-write), NOT the full buffer — the full buffer is
    aliased in place.  Without this, scan machinery (per-iteration xs
    slicing and carry updates) looks like it re-reads whole stacked arrays
    every iteration, inflating the memory term by orders of magnitude.
    """
    oc = op.opcode
    out_b = _bytes_of(op.type_str)
    args = _split_args(op.rest)

    def arg_bytes(i: int) -> float:
        if i < len(args):
            a = args[i].lstrip("%")
            if a in comp.shapes:
                return _bytes_of(comp.shapes[a])
        return 0.0

    if oc in _OUTPUT_ONLY:
        return out_b
    if oc == "dynamic-update-slice":
        return 2.0 * arg_bytes(1)
    if oc == "scatter":
        return 2.0 * arg_bytes(2) + arg_bytes(1)
    if oc == "select-and-scatter":
        return out_b + arg_bytes(1)
    b = out_b
    seen = set()
    for a in args:
        a = a.lstrip("%")
        if a in comp.shapes and a not in seen:
            seen.add(a)
            b += _bytes_of(comp.shapes[a])
    return b


def analyze(text: str, default_group: int = 1) -> Metrics:
    comps = _parse_module(text)
    entry = None
    for raw in text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(raw.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    memo: Dict[str, Metrics] = {}

    def visit(name: str) -> Metrics:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        met = Metrics()
        memo[name] = met
        if comp is None:
            return met
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                met.flops += _dot_flops(comp, op)
            elif oc == "convolution":
                met.flops += _conv_flops(comp, op)
            base = oc.replace("-start", "")
            if base in _COLL_OPS and not oc.endswith("-done"):
                size = _bytes_of(op.type_str)
                n = _group_size(op.rest, default_group)
                frac = (n - 1) / n if n > 1 else 0.0
                if base == "all-reduce":
                    lb = 2.0 * frac * size
                elif base == "reduce-scatter":
                    lb = frac * size * n
                elif base == "collective-permute":
                    lb = float(size)
                else:
                    lb = frac * size
                met.coll_link_bytes[base] += lb
                met.coll_counts[base] += 1
                met.bytes += _bytes_of(op.type_str)
            # bytes
            if oc not in _SKIP_BYTES and base not in _COLL_OPS:
                met.bytes += _op_bytes(comp, op)
            # control flow
            if oc in _CONTROL:
                scale = 1.0
                if oc == "while":
                    tm = _TRIP_RE.search(op.rest)
                    scale = float(tm.group(1)) if tm else 1.0
                called = _called_comps(op)
                if oc == "while":
                    # body only (condition negligible)
                    body = [c for c in called if "cond" not in c] or called
                    for c in body[:1]:
                        met.add(visit(c), scale)
                elif oc == "conditional":
                    branches = [visit(c) for c in called]
                    if branches:
                        # upper bound: the most expensive branch
                        best = max(branches, key=lambda m_: m_.flops + m_.bytes)
                        met.add(best, 1.0)
                elif oc == "fusion":
                    # fusion internals are registers/cache, not HBM traffic;
                    # the call site's operands+output were counted above
                    for c in called:
                        met.add(visit(c), 1.0, include_bytes=False)
                else:  # call
                    for c in called:
                        met.add(visit(c), 1.0)
        return met

    return visit(entry)
