import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the XLA_FLAGS assignment above MUST precede every other import
# (jax locks the device count on first init), which is why this module has
# no ``from __future__ import annotations`` and no module docstring first.

# Multi-pod dry-run (deliverable e).
#
# Lowers + compiles every (architecture x shape x mesh) cell against the
# production meshes (16x16 single-pod, 2x16x16 multi-pod) on 512 placeholder
# CPU devices, records ``memory_analysis`` / ``cost_analysis`` and the
# trip-count-aware HLO roofline terms (deliverable g).
#
# Single cell:   python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
# Multi-pod:     ... --multi-pod
# Whole table:   python -m repro.launch.dryrun --all    (subprocess per cell,
#                resumable via the JSON artifact cache)

import argparse
import json
import subprocess
import sys
import time
from typing import Any, Dict

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "benchmarks", "artifacts",
    "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False, overrides: "Dict[str, Any] | None" = None,
             n_micro: "int | None" = None, grad_dtype: "str | None" = None,
             fsdp: "bool | None" = None,
             gather_once: bool = False) -> Dict[str, Any]:
    import jax
    from repro.configs import get_config
    from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                            param_shardings, replicated)
    from repro.launch import hlo_cost
    from repro.launch.hlo_analysis import roofline_terms
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import TRAIN_MICROBATCHES, input_specs
    from repro.models.config import SHAPES
    from repro.train.optimizer import AdamWConfig, init_opt
    from repro.train.step import (make_decode_step, make_prefill_step,
                                  make_train_step)
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # §Perf default (hillclimb A3/A8): single-tile attention for short-seq
    # training (chunking only pays for memory at 32k+), 2048-token loss chunks
    merged = {}
    if shape.kind == "train" and cfg.has_attention:
        tile = min(shape.seq_len, 4096)
        merged.update(q_chunk=tile, kv_chunk=tile)
        if 0 < cfg.num_kv_heads < 16 <= cfg.num_heads:
            merged.update(repeat_kv=True)  # §Perf C2: clean head sharding
    if shape.kind == "train":
        merged.update(loss_chunk=min(2048, cfg.loss_chunk * 4)
                      if cfg.vocab_size > 100_000 else 2048)
    merged.update(overrides or {})
    if merged:
        import dataclasses
        cfg = dataclasses.replace(cfg, **merged)
    mesh = make_production_mesh(multi_pod=multi_pod)
    jax.sharding.set_mesh(mesh)  # ambient mesh for activation hints
    chips = 1
    for n in mesh.shape.values():
        chips *= n
    specs = input_specs(cfg, shape)

    # per-arch memory policy: ZeRO-3/FSDP only where model-parallel-only
    # state would overflow HBM; the 1T MoE uses Adafactor + bf16 grads
    if fsdp is None:
        fsdp = arch in ("qwen2.5-14b", "pixtral-12b")
    huge = cfg.param_count() > 2e11
    param_sh = param_shardings(mesh, specs["params"], fsdp=fsdp)

    t0 = time.time()
    if shape.kind == "train":
        if huge:
            opt_cfg = AdamWConfig(mode="adafactor", momentum=False,
                                  state_dtype="float32",
                                  grad_dtype="bfloat16")
        else:
            opt_cfg = AdamWConfig(grad_dtype=grad_dtype or "float32")
        opt_specs = jax.eval_shape(lambda p: init_opt(opt_cfg, p),
                                   specs["params"])
        # optimizer state shards like its parameter; the factored-v tree is
        # path-compatible modulo the trailing {row,col} dicts, which the
        # rule matcher resolves by leaf rank (rank mismatch -> replicated,
        # rows/cols are small)
        opt_sh = type(opt_specs)(
            step=replicated(mesh),
            m=(param_shardings(mesh, opt_specs.m, fsdp=fsdp)
               if opt_specs.m != () else ()),
            v=jax.tree_util.tree_map(lambda _: replicated(mesh), opt_specs.v)
            if opt_cfg.mode == "adafactor"
            else param_shardings(mesh, opt_specs.v, fsdp=fsdp))
        batch_sh = batch_shardings(mesh, specs["batch"])
        if n_micro is None:
            n_micro = TRAIN_MICROBATCHES.get(arch, 4)
        fn = make_train_step(cfg, opt_cfg, n_micro=n_micro,
                              grad_shardings=param_sh,
                              gather_weights_once=gather_once)
        metrics_sh = {"loss": replicated(mesh), "grad_norm": replicated(mesh),
                      "step": replicated(mesh)}
        jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, metrics_sh))
        lowered = jitted.lower(specs["params"], opt_specs, specs["batch"])
    elif shape.kind == "prefill":
        batch_sh = batch_shardings(mesh, specs["batch"])
        ba = ("pod", "data") if multi_pod else ("data",)
        vshard = "model" if cfg.vocab_size % 16 == 0 else None
        logits_sh = NamedSharding(mesh, P(ba, vshard))
        if cfg.is_encoder_only:
            from repro.models import embed_inputs, forward_hidden
            from repro.models.layers import apply_norm, unembed_table
            import jax.numpy as jnp

            def fn(params, batch):
                h = embed_inputs(cfg, params, batch)
                S = h.shape[1]
                pos = jnp.arange(S, dtype=jnp.int32)
                h, _ = forward_hidden(cfg, params, h, positions=pos)
                h = apply_norm(cfg, params["final_norm"], h)
                W = unembed_table(cfg, params["embed"])
                return jnp.einsum("bsd,vd->bsv", h,
                                  W.astype(h.dtype))  # frame unit logits

            out_sh = NamedSharding(mesh, P(ba, None, vshard))
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            cache_sh = cache_shardings(mesh, cfg, specs["cache"],
                                       shape.global_batch)
            fn = make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh, cache_sh),
                             out_shardings=(logits_sh, cache_sh))
            lowered = jitted.lower(specs["params"], specs["batch"],
                                   specs["cache"])
    else:  # decode
        cache_sh = cache_shardings(mesh, cfg, specs["cache"],
                                   shape.global_batch)
        ba = ("pod", "data") if multi_pod else ("data",)
        shard_batch = shape.global_batch % (16 * (2 if multi_pod else 1)) == 0
        tok_sh = NamedSharding(mesh, P(ba if shard_batch else None, None))
        vshard = "model" if cfg.vocab_size % 16 == 0 else None
        logits_sh = NamedSharding(mesh, P(ba if shard_batch else None,
                                          vshard))
        fn = make_decode_step(cfg)
        jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh,
                                           replicated(mesh)),
                         out_shardings=(logits_sh, cache_sh))
        lowered = jitted.lower(specs["params"], specs["cache"],
                               specs["tokens"], specs["pos"])
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    metrics = hlo_cost.analyze(txt)
    terms = roofline_terms(metrics.flops, metrics.bytes,
                           metrics.total_link_bytes)

    # MODEL_FLOPS (useful-compute yardstick), per device
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    model_flops_dev = model_flops / chips

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "ok": True, "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops_single_visit": cost.get("flops", 0.0),
            "bytes_single_visit": cost.get("bytes accessed", 0.0),
        },
        "hlo_analyzer": {
            "flops_per_device": metrics.flops,
            "hbm_bytes_per_device": metrics.bytes,
            "collective_link_bytes_per_device": metrics.total_link_bytes,
            "collective_breakdown": metrics.coll_link_bytes,
            "collective_counts": metrics.coll_counts,
        },
        "roofline": terms,
        "model_flops_per_device": model_flops_dev,
        "useful_fraction": (model_flops_dev / metrics.flops
                            if metrics.flops else 0.0),
    }
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    import gzip
    hpz = os.path.join(ARTIFACT_DIR,
                       f"{arch}_{shape_name}_{result['mesh']}.hlo.txt.gz")
    with gzip.open(hpz, "wt") as f:
        f.write(txt)
    result["hlo_gz"] = hpz
    if save_hlo:
        hp = os.path.join(ARTIFACT_DIR,
                          f"{arch}_{shape_name}_{result['mesh']}.hlo.txt")
        with open(hp, "w") as f:
            f.write(txt)
        result["hlo_path"] = hp
    return result


def cell_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(ARTIFACT_DIR, f"{arch}_{shape}_{mesh}.json")


def run_all(force: bool = False, timeout_s: int = 3000) -> None:
    from repro.configs import ARCH_IDS, shape_cells, skipped_cells

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    table = []
    for arch in ARCH_IDS:
        for shape in shape_cells(arch):
            for mesh_flag, mesh_name in ((False, "16x16"), (True, "2x16x16")):
                path = cell_path(arch, shape, mesh_name)
                if os.path.exists(path) and not force:
                    table.append(json.load(open(path)))
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", path]
                if mesh_flag:
                    cmd.append("--multi-pod")
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout_s)
                if r.returncode != 0:
                    fail = {"arch": arch, "shape": shape, "mesh": mesh_name,
                            "ok": False, "error": r.stderr[-4000:]}
                    with open(path, "w") as f:
                        json.dump(fail, f, indent=2)
                    table.append(fail)
                    print(f"  FAILED in {time.time()-t0:.0f}s:\n{r.stderr[-2000:]}")
                else:
                    table.append(json.load(open(path)))
                    print(f"  ok in {time.time()-t0:.0f}s")
        for shape, why in skipped_cells(arch).items():
            table.append({"arch": arch, "shape": shape, "mesh": "-",
                          "ok": "skip", "why": why})
    summary = os.path.join(ARTIFACT_DIR, "summary.json")
    with open(summary, "w") as f:
        json.dump(table, f, indent=2)
    bad = [t for t in table if t["ok"] is False]
    print(f"\n{len(table)} cells recorded; {len(bad)} failures -> {summary}")
    if bad:
        for t in bad:
            print("  FAIL:", t["arch"], t["shape"], t["mesh"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()
    if args.all:
        run_all(force=args.force)
        return
    res = run_cell(args.arch, args.shape, args.multi_pod,
                   save_hlo=args.save_hlo)
    js = json.dumps(res, indent=2)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
