"""ShapeDtypeStruct input stand-ins per (architecture x shape cell).

``input_specs`` returns abstract arrays only — weak-type-correct, shardable,
zero device allocation — exactly what ``jax.jit(...).lower()`` needs for the
multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import init_cache, init_params
from repro.models.config import ModelConfig, ShapeConfig

# train_4k gradient-accumulation microbatch count per arch (memory knob;
# per-microbatch rows = global_batch / n_micro)
TRAIN_MICROBATCHES = {
    "olmo-1b": 4, "qwen1.5-0.5b": 4, "mamba2-370m": 4, "hubert-xlarge": 4,
    "yi-6b": 8, "olmoe-1b-7b": 8,
    "qwen2.5-14b": 16, "pixtral-12b": 16, "zamba2-7b": 16,
    "kimi-k2-1t-a32b": 32,
}


def batch_specs(cfg: ModelConfig, batch: int, seq: int,
                with_labels: bool = True) -> Dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    if cfg.frontend in ("tokens", "patch_embed"):
        out["tokens"] = sds((batch, seq), jnp.int32)
        if cfg.frontend == "patch_embed":
            out["patch_embeds"] = sds(
                (batch, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    else:  # frame_embed
        out["frames"] = sds((batch, seq, cfg.d_model), jnp.bfloat16)
    if with_labels:
        out["labels"] = sds((batch, seq), jnp.int32)
    return out


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype=jnp.bfloat16))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All abstract inputs for the cell's step function."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"params": params_specs(cfg),
                "batch": batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        specs = {"params": params_specs(cfg),
                 "batch": batch_specs(cfg, B, S, with_labels=False)}
        if not cfg.is_encoder_only:
            specs["cache"] = cache_specs(cfg, B, S)
        return specs
    if shape.kind == "decode":
        return {"params": params_specs(cfg),
                "cache": cache_specs(cfg, B, S),
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)
