"""Roofline-term extraction from compiled SPMD artifacts (deliverable g).

``collective_bytes`` parses post-optimization HLO text and estimates the
per-device link bytes of every collective with ring formulas:

    all-reduce       2 (n-1)/n * size      (size = output bytes)
    all-gather         (n-1)/n * size      (size = output bytes)
    reduce-scatter     (n-1)/n * size      (size = input  = output * n)
    all-to-all         (n-1)/n * size
    collective-permute          1 * size

where n is the replica-group size parsed from ``replica_groups=[g,n]<=...``
(or counted from explicit ``{{...}}`` groups).  Sizes are the per-device
HLO shapes (the module is the per-partition program).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g.  bf16[8,128]{1,0}  or  f32[]
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        first = m.group(1).strip("{}")
        return len([x for x in first.split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    raw_bytes: Dict[str, float]     # sum of parsed shapes
    link_bytes: Dict[str, float]    # ring-model per-device link traffic

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())


def collective_bytes(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    counts = {op: 0 for op in _COLL_OPS}
    raw = {op: 0.0 for op in _COLL_OPS}
    link = {op: 0.0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # match '<lhs type> opcode(' — opcode right after the '=' type
        m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.+?)\s+([a-z0-9\-]+)\(", s)
        if not m:
            continue
        type_str, opcode = m.group(1), m.group(2)
        # skip fused users / '-start' '-done' duplicates: count only starts
        base = opcode.replace("-start", "")
        if base not in _COLL_OPS or opcode.endswith("-done"):
            continue
        size = _shape_bytes(type_str)
        n = _group_size(s, default_group)
        counts[base] += 1
        raw[base] += size
        frac = (n - 1) / n if n > 1 else 0.0
        if base == "all-reduce":
            link[base] += 2.0 * frac * size
        elif base == "all-gather":
            link[base] += frac * size
        elif base == "reduce-scatter":
            link[base] += frac * size * n          # size parsed = output
        elif base == "all-to-all":
            link[base] += frac * size
        elif base == "collective-permute":
            link[base] += size
    return CollectiveStats(counts, raw, link)


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float,
                   ) -> Dict[str, float]:
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm_bytes / HBM_BW
    t_coll = link_bytes / LINK_BW
    terms = {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["bound_s"] = bound
    terms["roofline_fraction"] = (t_comp / bound) if bound > 0 else 0.0
    return terms
