"""Jitted public wrappers around the GF(2^8) matmul kernel.

``gf_matmul`` pads to block multiples, dispatches to the Pallas kernel (on
TPU) or its interpret-mode execution (CPU), and slices the result.  Padding
with zeros is sound: 0 is the additive identity of GF(2^8) and 0*x = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .gf_matmul import gf_matmul_pallas
from .ref import gf_matmul_ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _padded_call(a, b, bm, bn, bk, interpret):
    m, k = a.shape
    _, n = b.shape
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    if (mp, kp, np_) == (m, k, n):
        # already block multiples: skip the padding copy on the hot path
        return gf_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    # jnp.pad appends zero margins without materializing a full zero buffer
    # first (the old zeros().at[].set() built and then overwrote one)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = gf_matmul_pallas(a_p, b_p, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def gf_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 512,
              interpret: bool | None = None) -> jnp.ndarray:
    """GF(2^8) matmul with automatic padding; kernel on TPU, interpret on CPU."""
    a = jnp.asarray(a, jnp.uint8)
    b = jnp.asarray(b, jnp.uint8)
    if interpret is None:
        interpret = not _on_tpu()
    return _padded_call(a, b, bm, bn, bk, interpret)


def gf_matmul_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Kernel-backed matmul with a numpy interface (pluggable into
    :class:`repro.coding.rlnc.RLNC` to run the coding plane through the
    kernel end-to-end)."""
    return np.asarray(gf_matmul(np.asarray(a, np.uint8), np.asarray(b, np.uint8)))


def gf_matmul_reference(a, b) -> jnp.ndarray:
    """Pure-jnp oracle (no Pallas), exported for benchmarks/tests."""
    return gf_matmul_ref(jnp.asarray(a, jnp.uint8), jnp.asarray(b, jnp.uint8))
