"""Jitted public wrappers around the GF(2^8) matmul kernel.

``gf_matmul`` pads to block multiples, dispatches to the Pallas kernel (on
TPU) or its interpret-mode execution (CPU), and slices the result.  Padding
with zeros is sound: 0 is the additive identity of GF(2^8) and 0*x = 0.

If the Pallas path raises on a host whose jax build cannot lower or
interpret the kernel, ``gf_matmul`` falls back to the pure-jnp reference
implementation once per process (a ``RuntimeWarning`` is emitted on the
first trip) so coding-plane callers keep working on any CPU.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from .gf_matmul import gf_matmul_pallas
from .ref import gf_matmul_ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _padded_call(a, b, bm, bn, bk, interpret):
    m, k = a.shape
    _, n = b.shape
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    if (mp, kp, np_) == (m, k, n):
        # already block multiples: skip the padding copy on the hot path
        return gf_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    # jnp.pad appends zero margins without materializing a full zero buffer
    # first (the old zeros().at[].set() built and then overwrote one)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = gf_matmul_pallas(a_p, b_p, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


# Mutable cell rather than a bare global so tests can reset it via
# monkeypatch.setitem; "active" latches True after the first Pallas failure
# and routes every later call straight to the reference path (warn once).
_fallback = {"active": False}

_gf_matmul_ref_jit = jax.jit(gf_matmul_ref)


def gf_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 512,
              interpret: bool | None = None) -> jnp.ndarray:
    """GF(2^8) matmul with automatic padding; kernel on TPU, interpret on CPU.

    Falls back to the jitted pure-jnp reference (same results, no Pallas)
    if the kernel path raises — some CPU-only jax builds cannot even
    interpret Pallas calls, and the coding plane must not die with them.
    """
    a = jnp.asarray(a, jnp.uint8)
    b = jnp.asarray(b, jnp.uint8)
    if _fallback["active"]:
        return _gf_matmul_ref_jit(a, b)
    if interpret is None:
        interpret = not _on_tpu()
    try:
        return _padded_call(a, b, bm, bn, bk, interpret)
    except Exception as exc:  # pragma: no branch - single fallback trip
        _fallback["active"] = True
        warnings.warn(
            f"Pallas GF(2^8) kernel unavailable on this host ({exc!r}); "
            "falling back to the pure-jnp reference implementation",
            RuntimeWarning, stacklevel=2)
        return _gf_matmul_ref_jit(a, b)


def gf_matmul_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Kernel-backed matmul with a numpy interface (pluggable into
    :class:`repro.coding.rlnc.RLNC` to run the coding plane through the
    kernel end-to-end)."""
    return np.asarray(gf_matmul(np.asarray(a, np.uint8), np.asarray(b, np.uint8)))


def gf_matmul_reference(a, b) -> jnp.ndarray:
    """Pure-jnp oracle (no Pallas), exported for benchmarks/tests."""
    return gf_matmul_ref(jnp.asarray(a, jnp.uint8), jnp.asarray(b, jnp.uint8))
