"""Pure-jnp oracle for GF(2^8) matrix multiplication.

Bit-plane algorithm (the same math the Pallas kernel uses, unblocked):

  * expand A and B into 8 one-bit planes each;
  * carry-less polynomial product: plane t of the 15-coefficient product is
    the GF(2) (parity) sum over i+j=t of  A_i @ B_j  — each an ordinary
    integer matmul of 0/1 matrices (this is what lands on the TPU MXU);
  * reduce the 15 planes mod x^8+x^4+x^3+x^2+1 (0x11D):  x^8 == 0x1D, so
    plane t >= 8 folds into planes t-8+{0,2,3,4} (processed high-to-low);
  * reassemble the 8 low planes into bytes.

Parity can be taken once after the full K accumulation because XOR == sum
mod 2 and int32 counts cannot overflow for K < 2^28.
"""
from __future__ import annotations

import jax.numpy as jnp

# bit positions of 0x1D = x^4 + x^3 + x^2 + 1 (x^8 reduced)
_FOLD = (0, 2, 3, 4)


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B over GF(2^8), A:(M,K) uint8, B:(K,N) uint8 -> (M,N) uint8."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0], (
        a.shape, b.shape)
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    abits = [((a32 >> i) & 1) for i in range(8)]
    bbits = [((b32 >> j) & 1) for j in range(8)]
    planes = []
    for t in range(15):
        acc = None
        for i in range(max(0, t - 7), min(7, t) + 1):
            j = t - i
            term = jnp.matmul(abits[i], bbits[j])
            acc = term if acc is None else acc + term
        planes.append(acc & 1)
    for t in range(14, 7, -1):
        p = planes[t]
        for s in _FOLD:
            planes[t - 8 + s] = planes[t - 8 + s] ^ p
    out = planes[0]
    for t in range(1, 8):
        out = out | (planes[t] << t)
    return out.astype(jnp.uint8)
