"""Pallas TPU kernel: GF(2^8) matrix multiplication (RLNC encode/decode).

TPU adaptation of the paper's coding hot-spot (DESIGN.md §3).  GPU codes use
log/exp lookup tables in shared memory; VMEM gathers are slow on TPU, so we
decompose the field product into 8x8 = 64 one-bit-plane integer matmuls that
run on the MXU at int8 throughput, XOR being parity of the int32 count:

    C = reduce_mod_0x11D( planes[t] ),
    planes[t] = (sum_{i+j=t} A_i @ B_j) & 1,   A_i = (A >> i) & 1.

Blocking: grid (M/bm, N/bn, K/bk), K innermost.  Per grid step the kernel
issues 64 (bm,bk)x(bk,bn) int8 dots accumulated into a 15-plane int32 VMEM
scratch; the final K step takes parity, folds planes 14..8 (x^8 == 0x1D) and
writes bytes.  VMEM at the default bm=bn=128, bk=512: A 64K + B 64K + out
16K + scratch 15*128*128*4 = 983K — comfortably inside ~16 MB VMEM, with
MXU-aligned (128-multiple) dot shapes.

Roofline: one GF(2^8) MAC costs 64 int8-MXU MACs (2x bf16 rate), so the
kernel's compute ceiling is 197e12*2/64 ≈ 6.2e12 GF-MAC/s/chip; arithmetic
intensity matches a regular matmul, so blocks this size are compute-bound.
Validated against ``ref.gf_matmul_ref`` and the table-based numpy oracle in
interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across JAX releases;
# support both so the kernel (and its interpret-mode path) runs everywhere
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

_FOLD = (0, 2, 3, 4)  # x^8 == x^4 + x^3 + x^2 + 1


def _gf_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)  # (bm, bk) bytes
    b = b_ref[...].astype(jnp.int32)  # (bk, bn) bytes
    abits = [((a >> i) & 1).astype(jnp.int8) for i in range(8)]
    bbits = [((b >> j) & 1).astype(jnp.int8) for j in range(8)]
    for t in range(15):
        acc = acc_ref[t]
        for i in range(max(0, t - 7), min(7, t) + 1):
            acc = acc + jax.lax.dot(abits[i], bbits[t - i],
                                    preferred_element_type=jnp.int32)
        acc_ref[t] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        planes = [acc_ref[t] & 1 for t in range(15)]
        for t in range(14, 7, -1):
            p = planes[t]
            for s in _FOLD:
                planes[t - 8 + s] = planes[t - 8 + s] ^ p
        out = planes[0]
        for t in range(1, 8):
            out = out | (planes[t] << t)
        o_ref[...] = out.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gf_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                     bn: int = 128, bk: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """C = A @ B over GF(2^8).  Shapes must be multiples of the block sizes
    (use :func:`repro.kernels.ops.gf_matmul` for automatic padding)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes {(m, k, n)} not multiples of blocks {(bm, bk, bn)}")
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_gf_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((15, bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
