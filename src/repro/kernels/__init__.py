"""Pallas TPU kernels for the coding hot-spot (GF(2^8) matmul).

The LM dry-run stack is pure XLA (it must lower on the CPU backend with 512
placeholder devices); kernels here serve the paper's RLNC coding plane.
"""
from .ops import gf_matmul, gf_matmul_numpy, gf_matmul_reference
from .gf_matmul import gf_matmul_pallas
from .ref import gf_matmul_ref

__all__ = ["gf_matmul", "gf_matmul_numpy", "gf_matmul_reference",
           "gf_matmul_pallas", "gf_matmul_ref"]
