"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
(target units); encoder-only, non-causal; conv feature extractor is a STUB
(precomputed frame embeddings) [arXiv:2106.07447; unverified]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
        d_ff=5120, vocab_size=504, num_heads=16, num_kv_heads=16,
        head_dim=80, causal=False, frontend="frame_embed",
        norm="layernorm")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio", num_layers=2, d_model=64,
        d_ff=128, vocab_size=64, num_heads=4, num_kv_heads=4, head_dim=16,
        causal=False, frontend="frame_embed", norm="layernorm", q_chunk=16,
        kv_chunk=16, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
