"""Assigned-architecture registry: ``get_config(name)`` / ``get_smoke_config``.

Each module defines ``full_config()`` (the exact published shape) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "zamba2-7b",
    "mamba2-370m",
    "olmo-1b",
    "qwen2.5-14b",
    "yi-6b",
    "qwen1.5-0.5b",
    "kimi-k2-1t-a32b",
    "olmoe-1b-7b",
    "pixtral-12b",
    "hubert-xlarge",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).full_config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def shape_cells(name: str) -> List[str]:
    """The runnable shape cells for an arch; skips per DESIGN.md §4."""
    cfg = get_config(name)
    cells = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder_only:
        cells.append("decode_32k")
        if cfg.sub_quadratic:
            cells.append("long_500k")
    return cells


def skipped_cells(name: str) -> Dict[str, str]:
    cfg = get_config(name)
    skips = {}
    if cfg.is_encoder_only:
        skips["decode_32k"] = "encoder-only: no autoregressive decode step"
        skips["long_500k"] = "encoder-only: no decode; full attention is O(L^2)"
    elif not cfg.sub_quadratic:
        skips["long_500k"] = "pure full-attention arch: not sub-quadratic"
    return skips
