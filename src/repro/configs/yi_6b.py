"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000;
llama-style GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense", num_layers=32, d_model=4096,
        d_ff=11008, vocab_size=64000, num_heads=32, num_kv_heads=4,
        head_dim=128, rope_theta=5e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense", num_layers=2, d_model=64,
        d_ff=176, vocab_size=256, num_heads=8, num_kv_heads=2, head_dim=8,
        rope_theta=5e6, q_chunk=16, kv_chunk=16, loss_chunk=16,
        param_dtype="float32", compute_dtype="float32")
