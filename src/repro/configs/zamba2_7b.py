"""zamba2-7b [hybrid]: 81 Mamba2 layers d_model=3584 ssm_state=64 + two
weight-shared attention blocks (32H, d_ff=14336) applied every 6 layers;
vocab=32000 [arXiv:2411.15242; unverified]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
        d_ff=14336, vocab_size=32000, num_heads=32, num_kv_heads=32,
        head_dim=112, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
        ssm_conv=4, ssm_chunk=256, shared_attn_every=6, num_shared_blocks=2,
        rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
        d_ff=128, vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=8,
        shared_attn_every=2, num_shared_blocks=2, rope_theta=10_000.0,
        q_chunk=16, kv_chunk=16, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
