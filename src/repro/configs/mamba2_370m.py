"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, ssm_state=128
vocab=50280; SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
        d_ff=0, vocab_size=50280, ssm_state=128, ssm_expand=2,
        ssm_head_dim=64, ssm_conv=4, ssm_chunk=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
        d_ff=0, vocab_size=256, ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        ssm_conv=4, ssm_chunk=8, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
