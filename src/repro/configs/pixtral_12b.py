"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend is a STUB (precomputed patch embeddings
for the first 1024 positions) [hf:mistralai/Pixtral-12B-2409; unverified]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", num_layers=40, d_model=5120,
        d_ff=14336, vocab_size=131072, num_heads=32, num_kv_heads=8,
        head_dim=160, rope_theta=1e9, frontend="patch_embed",
        num_frontend_tokens=1024, loss_chunk=512)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm", num_layers=2, d_model=64,
        d_ff=128, vocab_size=256, num_heads=8, num_kv_heads=2, head_dim=8,
        rope_theta=1e9, frontend="patch_embed", num_frontend_tokens=8,
        q_chunk=16, kv_chunk=16, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
