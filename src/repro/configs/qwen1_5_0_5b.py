"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936; QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense", num_layers=24, d_model=1024,
        d_ff=2816, vocab_size=151936, num_heads=16, num_kv_heads=16,
        head_dim=64, qkv_bias=True, rope_theta=1e6, loss_chunk=512)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b-smoke", family="dense", num_layers=2, d_model=48,
        d_ff=96, vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=12,
        qkv_bias=True, rope_theta=1e6, q_chunk=16, kv_chunk=16,
        loss_chunk=16, param_dtype="float32", compute_dtype="float32")
