"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
        d_ff=13824, vocab_size=152064, num_heads=40, num_kv_heads=8,
        head_dim=128, qkv_bias=True, rope_theta=1e6, loss_chunk=512)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", family="dense", num_layers=2, d_model=64,
        d_ff=160, vocab_size=256, num_heads=8, num_kv_heads=2, head_dim=8,
        qkv_bias=True, rope_theta=1e6, q_chunk=16, kv_chunk=16,
        loss_chunk=16, param_dtype="float32", compute_dtype="float32")
