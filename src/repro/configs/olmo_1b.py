"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense", num_layers=16, d_model=2048,
        d_ff=8192, vocab_size=50304, num_heads=16, num_kv_heads=16,
        head_dim=128, norm="nonparam_ln", rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke", family="dense", num_layers=2, d_model=64,
        d_ff=128, vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16,
        norm="nonparam_ln", rope_theta=10_000.0, q_chunk=16, kv_chunk=16,
        loss_chunk=16, param_dtype="float32", compute_dtype="float32")
