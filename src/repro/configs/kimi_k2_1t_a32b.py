"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840; 384 experts top-8 (trillion-param MoE)
[arXiv:2501.kimi2; unverified]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
        d_ff=2048, vocab_size=163840, num_heads=64, num_kv_heads=8,
        head_dim=112, num_experts=384, experts_per_token=8,
        rope_theta=5e7, loss_chunk=512)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe", num_layers=2, d_model=64,
        d_ff=32, vocab_size=256, num_heads=8, num_kv_heads=2, head_dim=8,
        num_experts=8, experts_per_token=2, rope_theta=5e7, q_chunk=16,
        kv_chunk=16, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
