"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304; 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
        d_ff=1024, vocab_size=50304, num_heads=16, num_kv_heads=16,
        head_dim=128, num_experts=64, experts_per_token=8,
        rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe", num_layers=2, d_model=64,
        d_ff=32, vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16,
        num_experts=4, experts_per_token=2, rope_theta=10_000.0, q_chunk=16,
        kv_chunk=16, loss_chunk=16, param_dtype="float32",
        compute_dtype="float32")
