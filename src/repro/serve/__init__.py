"""Batched serving engine over the model zoo's prefill/decode API."""
from .engine import Completion, Request, ServeEngine

__all__ = ["Completion", "Request", "ServeEngine"]
