"""Batched serving engine: continuous batched prefill + decode on the
models' (prefill, decode_step) API, with per-slot position tracking.

Static-shape design (XLA-friendly): a fixed number of slots, one shared
KV/state cache of max_len, greedy or temperature sampling.  Requests beyond
the slot count queue FIFO; finished slots are refilled between decode
steps (continuous batching).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        assert not cfg.is_encoder_only, "decode serving needs a causal LM"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b, c: prefill(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    def _sample(self, logits: jnp.ndarray, temperature: float) -> int:
        if temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / temperature))

    def generate(self, requests: List[Request]) -> List[Completion]:
        """Simple sequential-slot scheduler: batches of ``slots`` requests,
        each prefilled as a batch then decoded lock-step until every slot
        finishes (per-slot early stop via done mask)."""
        out: List[Completion] = []
        queue = list(requests)
        while queue:
            chunk = queue[: self.slots]
            queue = queue[self.slots:]
            out.extend(self._run_batch(chunk))
        return out

    def _run_batch(self, chunk: List[Request]) -> List[Completion]:
        B = len(chunk)
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(chunk):
            # left-pad with token 0 so every prompt ends at index plen-1
            toks[i, plen - len(r.prompt):] = r.prompt
        cache = init_cache(self.cfg, B, self.max_len, dtype=jnp.float32
                           if self.cfg.param_dtype == "float32"
                           else jnp.bfloat16)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, cache)
        done = [False] * B
        results: List[List[int]] = [[] for _ in range(B)]
        cur = np.zeros((B, 1), np.int32)
        for i, r in enumerate(chunk):
            cur[i, 0] = self._sample(logits[i], r.temperature)
            results[i].append(int(cur[i, 0]))
        max_new = max(r.max_new_tokens for r in chunk)
        for t in range(1, max_new):
            pos = jnp.int32(plen + t - 1)
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur), pos)
            for i, r in enumerate(chunk):
                if done[i] or len(results[i]) >= r.max_new_tokens:
                    done[i] = True
                    continue
                cur[i, 0] = self._sample(logits[i], r.temperature)
                results[i].append(int(cur[i, 0]))
            if all(done):
                break
        return [Completion(rid=r.rid, tokens=results[i])
                for i, r in enumerate(chunk)]
