"""Unified planner API: a capability-aware scheme registry behind one
``plan()`` / ``plan_many()`` entry point.

The paper contributes a *family* of regeneration planners — star, FR, TR,
FTR, plus the Shah [6] and RCTREE [7] baselines — evaluated under one
harness, and new schemes keep landing.  Historically that family was wired
through three hand-synchronized dispatch tables (``core.SCHEMES``,
``core.batched.BATCHED_SCHEMES``, ``storage.simulator._WITNESS_SCHEMES``)
and every caller re-implemented its own engine selection and scalar-
fallback logic.  This module replaces all of that with a single registry:

* Each scheme is one :class:`SchemeSpec` declaring its capabilities —
  the scalar planner, the batched planner (or ``None``), whether the
  planners accept the ``witness=`` engine selector, and whether the scheme
  produces trees or stars.  Registration is one :func:`register_scheme`
  call (usable as a decorator), so the next scheme — e.g. the
  topology-aware selection of arXiv:1506.05579 — is a single-file plug-in.
* :func:`plan` plans one network, :func:`plan_many` a whole batch.  Both
  own engine resolution (``engine="auto" | "scalar" | "batched"``), kwarg
  forwarding (``witness=`` reaches exactly the schemes that declared it),
  and the scalar fallback for schemes without a batched planner — declared
  by the registry and announced by one RuntimeWarning per scheme per
  process when the batched engine was explicitly requested.

Engine resolution.  ``"auto"`` picks the cheapest correct engine for the
call shape: the scalar planner for a single network, the batched planner
(when registered) for a batch — falling back to the scalar loop *silently*
for schemes that declared ``batched=None``.  ``"batched"`` insists on the
vectorized engine and warns once per scheme when it has to fall back;
``"scalar"`` always runs the per-network oracle planners.

``SCHEMES`` / ``BATCHED_SCHEMES`` / ``plan_batch`` remain importable from
``repro.core`` as thin deprecation shims over the registry (one
DeprecationWarning per name per process) so external code keeps working.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from .params import CodeParams, OverlayNetwork, RepairPlan
from .star import plan_fr, plan_shah, plan_star
from .tree import plan_tr
from .ftr import plan_ftr
from .rctree import plan_rctree
from .batched import (BatchPlanResult, caps_tensor, plan_fr_batch,
                      plan_ftr_batch, plan_shah_batch, plan_star_batch,
                      plan_tr_batch, plans_from_batch)

__all__ = [
    "BATCHED_SCHEMES", "SCHEMES", "SchemeSpec", "get_scheme", "plan",
    "plan_many", "register_scheme", "scheme_names", "schemes",
    "unregister_scheme",
]

ScalarPlanner = Callable[..., RepairPlan]
BatchedPlanner = Callable[..., BatchPlanResult]
ENGINES = ("auto", "scalar", "batched")
TOPOLOGIES = ("star", "tree")


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One registered regeneration scheme and its declared capabilities.

    ``scalar`` is the per-network oracle planner ``(net, params, **kw) ->
    RepairPlan``; ``batched`` the vectorized planner ``(caps, params, **kw)
    -> BatchPlanResult`` or ``None`` when the scheme has not been
    vectorized (the dispatcher then runs the declared scalar fallback).
    ``accepts_witness`` marks planners taking the ``witness=`` selector for
    the traffic-minimal witness engine (exact level cut vs scipy LP);
    ``accepts_profile`` marks *batched* planners taking the ``profile=``
    hook (ISSUE 7: per-stage wall-time instrumentation, the
    ``repro.obs.profile.PlannerProfile`` contract); ``topology`` is
    ``"tree"`` for schemes that search regeneration trees and ``"star"``
    for direct-to-newcomer schemes.
    """

    name: str
    scalar: ScalarPlanner
    batched: Optional[BatchedPlanner] = None
    accepts_witness: bool = False
    accepts_profile: bool = False
    topology: str = "star"
    description: str = ""

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                             f"got {self.topology!r}")

    @property
    def produces_tree(self) -> bool:
        return self.topology == "tree"


_REGISTRY: Dict[str, SchemeSpec] = {}


def register_scheme(name: str, scalar: Optional[ScalarPlanner] = None, *,
                    batched: Optional[BatchedPlanner] = None,
                    accepts_witness: bool = False,
                    accepts_profile: bool = False, topology: str = "star",
                    description: str = "", replace: bool = False):
    """Register a scheme; usable directly or as a decorator.

    Direct form (returns the :class:`SchemeSpec`)::

        register_scheme("fr", plan_fr, batched=plan_fr_batch,
                        accepts_witness=True)

    Decorator form (returns the planner unchanged)::

        @register_scheme("topo", batched=plan_topo_batch, topology="tree")
        def plan_topo(net, params): ...

    ``replace=True`` allows overwriting an existing entry (tests, plugin
    reload); otherwise double registration raises ValueError.
    """
    def _register(fn: ScalarPlanner) -> SchemeSpec:
        if name in _REGISTRY and not replace:
            raise ValueError(f"scheme {name!r} is already registered; "
                             f"pass replace=True to overwrite")
        spec = SchemeSpec(name=name, scalar=fn, batched=batched,
                          accepts_witness=accepts_witness,
                          accepts_profile=accepts_profile,
                          topology=topology, description=description)
        _REGISTRY[name] = spec
        return spec

    if scalar is None:
        def _decorator(fn: ScalarPlanner) -> ScalarPlanner:
            _register(fn)
            return fn
        return _decorator
    return _register(scalar)


def unregister_scheme(name: str) -> None:
    """Remove a scheme from the registry (tests / plugin teardown)."""
    _REGISTRY.pop(get_scheme(name).name)


def get_scheme(name: str) -> SchemeSpec:
    """Resolve a scheme name, with an error that lists what is registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r}; registered schemes: "
                         f"{sorted(_REGISTRY)}") from None


def schemes() -> Tuple[SchemeSpec, ...]:
    """All registered specs, in registration order."""
    return tuple(_REGISTRY.values())


def scheme_names(batched: Optional[bool] = None,
                 topology: Optional[str] = None) -> Tuple[str, ...]:
    """Registered scheme names in registration order, optionally filtered
    by capability: ``batched=True`` keeps schemes with a vectorized
    planner, ``batched=False`` the declared scalar-only ones;
    ``topology="star"|"tree"`` filters by produced structure."""
    out = []
    for spec in _REGISTRY.values():
        if batched is not None and (spec.batched is not None) != batched:
            continue
        if topology is not None and spec.topology != topology:
            continue
        out.append(spec.name)
    return tuple(out)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

_warned_scalar_fallback: set = set()


def _warn_scalar_fallback(scheme: str, entry: str) -> None:
    """One warning per scheme per process — not one per call — when the
    batched engine was requested for a scheme registered without one."""
    if scheme not in _warned_scalar_fallback:
        _warned_scalar_fallback.add(scheme)
        warnings.warn(
            f"{entry}(engine='batched'): no batched planner registered for "
            f"{scheme!r} (the registry declares batched=None); falling back "
            f"to the scalar planner for all networks", RuntimeWarning,
            stacklevel=4)


def _planner_kwargs(spec: SchemeSpec, witness: str, kwargs: dict) -> dict:
    """Forward ``witness`` to exactly the schemes that declared it; other
    kwargs pass through verbatim (the planner rejects what it can't take)."""
    kw = dict(kwargs)
    if spec.accepts_witness:
        kw["witness"] = witness
    return kw


def _pstage(profile, name: str):
    """Stage-timing context: ``profile`` is any PlannerProfile-shaped
    object (``stage``/``count``/``note``, see ``repro.obs.profile`` — the
    contract is duck-typed so the planning core stays import-free of the
    observability package), or None for the zero-overhead default."""
    if profile is None:
        return contextlib.nullcontext()
    return profile.stage(name)


def _check_engine(engine: str) -> None:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")


def plan(net: OverlayNetwork, params: CodeParams, scheme: str,
         engine: str = "auto", witness: str = "exact",
         profile=None, **kwargs) -> RepairPlan:
    """Plan one regeneration of ``net`` with ``scheme``.

    ``engine="auto"`` (default) runs the scalar planner — the correctness
    oracle, and the cheapest engine for a single network.  ``"batched"``
    routes through the vectorized planner as a B=1 batch (falling back to
    scalar, with a once-per-scheme RuntimeWarning, when the registry
    declares no batched planner).  ``witness`` selects the traffic-minimal
    witness engine and reaches exactly the schemes that declared
    ``accepts_witness``; ``profile`` (optional, a
    ``repro.obs.profile.PlannerProfile``-shaped object) records the call
    shape and wall time — planners that declared ``accepts_profile``
    additionally time their internal stages; extra ``**kwargs`` (e.g.
    ``beta_max=`` for shah, ``region=`` for fr/ftr) are forwarded
    verbatim.  Profiling never changes what is planned.
    """
    _check_engine(engine)
    spec = get_scheme(scheme)
    kw = _planner_kwargs(spec, witness, kwargs)
    if engine == "batched" and spec.batched is None:
        _warn_scalar_fallback(scheme, "plan")
        engine = "scalar"
    if profile is not None:
        profile.note(scheme=spec.name, batch=1,
                     engine="batched" if engine == "batched" else "scalar")
    if engine == "batched":
        if spec.accepts_profile and profile is not None:
            kw["profile"] = profile
        with _pstage(profile, "total"):
            res = spec.batched(caps_tensor([net]), params, **kw)
        return plans_from_batch(res, params)[0]
    with _pstage(profile, "total"):
        return spec.scalar(net, params, **kw)


def plan_many(nets: Union[np.ndarray, Sequence[OverlayNetwork]],
              params: CodeParams, scheme: str, engine: str = "auto",
              witness: str = "exact", profile=None,
              **kwargs) -> BatchPlanResult:
    """Plan one scheme across a batch of networks.

    ``nets`` is either a ``(B, d+1, d+1)`` capacity tensor (see
    :func:`repro.core.caps_tensor`) or a sequence of
    :class:`OverlayNetwork`.  ``engine="auto"`` (default) uses the batched
    planner when the registry has one and the scalar loop otherwise —
    silently, because the fallback is *declared*; ``engine="batched"``
    additionally warns once per scheme when it has to fall back;
    ``engine="scalar"`` always runs the per-network oracle.  ``profile``
    (optional, ``repro.obs.profile.PlannerProfile``-shaped) records batch
    shape, resolved engine and wall time, plus per-stage timings for
    schemes that declared ``accepts_profile`` (fr/ftr: bisection,
    candidate search, witness extraction...) — without changing what is
    planned.

    The result's ``engine`` field reports which path actually planned the
    batch; on the scalar path the original :class:`RepairPlan` objects ride
    along in ``plans`` and ``plans_from_batch`` returns them verbatim.
    """
    _check_engine(engine)
    spec = get_scheme(scheme)
    kw = _planner_kwargs(spec, witness, kwargs)
    is_tensor = isinstance(nets, np.ndarray)
    if engine == "batched" and spec.batched is None:
        _warn_scalar_fallback(scheme, "plan_many")
    use_batched = spec.batched is not None and engine != "scalar"
    if profile is not None:
        profile.note(scheme=spec.name,
                     batch=int(nets.shape[0]) if is_tensor else len(nets),
                     d=params.d,
                     engine="batched" if use_batched else "scalar",
                     fallback=engine == "batched" and spec.batched is None)
    if use_batched:
        caps = nets if is_tensor else caps_tensor(nets)
        if spec.accepts_profile and profile is not None:
            kw["profile"] = profile
        with _pstage(profile, "total"):
            return spec.batched(caps, params, **kw)
    net_list = ([OverlayNetwork(c.tolist()) for c in nets] if is_tensor
                else list(nets))
    with _pstage(profile, "total"):
        plans = [spec.scalar(n, params, **kw) for n in net_list]
    return _batch_from_plans(spec, plans, params)


def _batch_from_plans(spec: SchemeSpec, plans: List[RepairPlan],
                      params: CodeParams) -> BatchPlanResult:
    """Pack scalar plans into a BatchPlanResult (the scalar-fallback path)."""
    d = params.d
    B = len(plans)
    parents = np.zeros((B, d + 1), dtype=np.int64)
    betas = np.zeros((B, d))
    lbs = np.full(B, np.nan)
    for b, p in enumerate(plans):
        for u in range(1, d + 1):
            parents[b, u] = p.parent[u]
        betas[b] = p.betas
        if p.lower_bound is not None:
            lbs[b] = p.lower_bound
    times = np.array([p.time for p in plans], dtype=np.float64)
    traffic = np.array([p.total_traffic for p in plans], dtype=np.float64)
    return BatchPlanResult(spec.name, times, traffic, betas, parents,
                           lower_bounds=None if np.isnan(lbs).all() else lbs,
                           engine="scalar", plans=plans)


# ---------------------------------------------------------------------------
# Built-in schemes (the paper's family)
# ---------------------------------------------------------------------------

register_scheme("star", plan_star, batched=plan_star_batch, topology="star",
                description="conventional uniform-beta star [3] (baseline)")
register_scheme("fr", plan_fr, batched=plan_fr_batch, accepts_witness=True,
                accepts_profile=True, topology="star",
                description="Flexible Regeneration on the star (Section III)")
register_scheme("tr", plan_tr, batched=plan_tr_batch, topology="tree",
                description="tree topology, uniform traffic (Algorithm 1)")
register_scheme("ftr", plan_ftr, batched=plan_ftr_batch, accepts_witness=True,
                accepts_profile=True, topology="tree",
                description="flexible traffic on a searched tree (Alg. 2)")
register_scheme("shah", plan_shah, batched=plan_shah_batch, topology="star",
                description="the (beta_max, gamma) scheme of Shah et al. [6]")
register_scheme("rctree", plan_rctree, batched=None, topology="tree",
                description="RCTREE [7], the MDS-violating prior scheme "
                            "(scalar only, declared)")


# ---------------------------------------------------------------------------
# Deprecation shims: the old dispatch tables, backed by the registry
# ---------------------------------------------------------------------------

_deprecation_warned: set = set()


def warn_deprecated(old: str, new: str) -> None:
    """One DeprecationWarning per legacy name per process."""
    if old not in _deprecation_warned:
        _deprecation_warned.add(old)
        warnings.warn(
            f"repro.core.{old} is deprecated; use repro.core.api.{new} "
            f"(the capability-aware scheme registry)", DeprecationWarning,
            stacklevel=4)


class _DeprecatedSchemeMap(Mapping):
    """Read-only live view of the registry behind a legacy dict name.

    Stays in sync with registrations (a newly registered scheme shows up
    immediately) and warns once per process on first use.
    """

    def __init__(self, name: str, replacement: str,
                 view: Callable[[], Dict[str, Callable]]):
        self._name = name
        self._replacement = replacement
        self._view = view

    def _touch(self) -> None:
        warn_deprecated(self._name, self._replacement)

    def __getitem__(self, key: str) -> Callable:
        self._touch()
        return self._view()[key]

    def __iter__(self) -> Iterator[str]:
        self._touch()
        return iter(self._view())

    def __len__(self) -> int:
        return len(self._view())

    def __repr__(self) -> str:  # no warning: repr is for debuggers
        return f"<deprecated {self._name} -> api.{self._replacement}: " \
               f"{sorted(self._view())}>"


SCHEMES = _DeprecatedSchemeMap(
    "SCHEMES", "plan() / get_scheme()",
    lambda: {name: spec.scalar for name, spec in _REGISTRY.items()})

BATCHED_SCHEMES = _DeprecatedSchemeMap(
    "BATCHED_SCHEMES", "plan_many() / get_scheme()",
    lambda: {name: spec.batched for name, spec in _REGISTRY.items()
             if spec.batched is not None})
