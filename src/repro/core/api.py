"""Unified planner API: a capability-aware scheme registry behind one
``plan()`` / ``plan_many()`` entry point.

The paper contributes a *family* of regeneration planners — star, FR, TR,
FTR, plus the Shah [6] and RCTREE [7] baselines — evaluated under one
harness, and new schemes keep landing.  Historically that family was wired
through three hand-synchronized dispatch tables (``core.SCHEMES``,
``core.batched.BATCHED_SCHEMES``, ``storage.simulator._WITNESS_SCHEMES``)
and every caller re-implemented its own engine selection and scalar-
fallback logic.  This module replaces all of that with a single registry:

* Each scheme is one :class:`SchemeSpec` declaring its capabilities —
  the scalar planner, the batched planner (or ``None``), whether the
  planners accept the ``witness=`` engine selector, and whether the scheme
  produces trees or stars.  Registration is one :func:`register_scheme`
  call (usable as a decorator), so the next scheme — e.g. the
  topology-aware selection of arXiv:1506.05579 — is a single-file plug-in.
* :func:`plan` plans one network, :func:`plan_many` a whole batch.  Both
  own engine resolution (``engine="auto" | "scalar" | "batched" | "jax"``),
  kwarg forwarding (``witness=`` reaches exactly the schemes that declared
  it), and the fallback chain for schemes without the requested engine —
  declared by the registry and announced by one RuntimeWarning per scheme
  per process when the missing engine was explicitly requested.

Engine resolution.  ``"auto"`` picks the cheapest correct engine for the
call shape: the scalar planner for a single network, the batched planner
(when registered) for a batch — falling back to the scalar loop *silently*
for schemes that declared ``batched=None``.  ``"batched"`` insists on the
vectorized engine and warns once per scheme when it has to fall back;
``"scalar"`` always runs the per-network oracle planners.  ``"jax"``
routes through the jit-compiled :mod:`repro.core.jax_engine` tier for the
schemes that declared one (star/fr/tr/ftr when jax is importable) and
falls back batched-then-scalar, warning once per scheme, otherwise.
``"auto"`` never resolves to jax: the NumPy planners stay the default
(and the golden-file oracle) on CPU; the jax tier is opt-in.

Ragged batches.  ``plan_many`` also accepts a *mixed fan-out* batch — a
sequence of overlays whose ``d`` differ (real repair events see whatever
helpers survive).  Overlays are bucketed by ``d``, each bucket planned in
one engine call against ``dataclasses.replace(params, d=...)``, and the
results reassembled in input order, padded to the widest ``d`` (see
:func:`plan_many`).

``SCHEMES`` / ``BATCHED_SCHEMES`` / ``plan_batch`` remain importable from
``repro.core`` as thin deprecation shims over the registry (one
DeprecationWarning per name per process) so external code keeps working.
"""
from __future__ import annotations

import contextlib
import dataclasses
import importlib.util
import warnings
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from .params import CodeParams, OverlayNetwork, RepairPlan
from .star import plan_fr, plan_shah, plan_star
from .tree import plan_tr
from .ftr import plan_ftr
from .rctree import plan_rctree
from .batched import (BatchPlanResult, caps_tensor, plan_fr_batch,
                      plan_ftr_batch, plan_shah_batch, plan_star_batch,
                      plan_tr_batch, plans_from_batch)

__all__ = [
    "BATCHED_SCHEMES", "SCHEMES", "SchemeSpec", "get_scheme", "plan",
    "plan_many", "register_scheme", "scheme_names", "schemes",
    "unregister_scheme",
]

ScalarPlanner = Callable[..., RepairPlan]
BatchedPlanner = Callable[..., BatchPlanResult]
ENGINES = ("auto", "scalar", "batched", "jax")
TOPOLOGIES = ("star", "tree")

HAS_JAX = importlib.util.find_spec("jax") is not None


def _lazy_jax(attr: str) -> Optional[BatchedPlanner]:
    """Deferred binding of a ``repro.core.jax_engine`` planner.

    Importing jax (and tracing/compiling kernels) costs seconds; the
    registry must stay cheap to import for the scalar/batched-only
    callers, so the jax module is imported on *first call*, not at
    registration.  Returns None when jax itself is absent from the
    environment — the spec then declares ``jax=None`` and the dispatcher
    falls back exactly as for any other missing engine.
    """
    if not HAS_JAX:
        return None

    def _call(caps, params, **kw):
        from . import jax_engine
        return getattr(jax_engine, attr)(caps, params, **kw)

    _call.__name__ = attr
    _call.__qualname__ = f"jax_engine.{attr}"
    return _call


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One registered regeneration scheme and its declared capabilities.

    ``scalar`` is the per-network oracle planner ``(net, params, **kw) ->
    RepairPlan``; ``batched`` the vectorized planner ``(caps, params, **kw)
    -> BatchPlanResult`` or ``None`` when the scheme has not been
    vectorized (the dispatcher then runs the declared scalar fallback).
    ``jax`` is the jit-compiled planner with the same batched signature,
    or ``None`` when the scheme has no JAX port (or jax is not importable
    in this environment) — the dispatcher then falls back batched-first.
    ``accepts_witness`` marks planners taking the ``witness=`` selector for
    the traffic-minimal witness engine (exact level cut vs scipy LP);
    ``accepts_profile`` marks *batched* planners taking the ``profile=``
    hook (ISSUE 7: per-stage wall-time instrumentation, the
    ``repro.obs.profile.PlannerProfile`` contract); ``topology`` is
    ``"tree"`` for schemes that search regeneration trees and ``"star"``
    for direct-to-newcomer schemes.
    """

    name: str
    scalar: ScalarPlanner
    batched: Optional[BatchedPlanner] = None
    jax: Optional[BatchedPlanner] = None
    accepts_witness: bool = False
    accepts_profile: bool = False
    topology: str = "star"
    description: str = ""

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                             f"got {self.topology!r}")

    @property
    def produces_tree(self) -> bool:
        return self.topology == "tree"


_REGISTRY: Dict[str, SchemeSpec] = {}


def register_scheme(name: str, scalar: Optional[ScalarPlanner] = None, *,
                    batched: Optional[BatchedPlanner] = None,
                    jax: Optional[BatchedPlanner] = None,
                    accepts_witness: bool = False,
                    accepts_profile: bool = False, topology: str = "star",
                    description: str = "", replace: bool = False):
    """Register a scheme; usable directly or as a decorator.

    Direct form (returns the :class:`SchemeSpec`)::

        register_scheme("fr", plan_fr, batched=plan_fr_batch,
                        accepts_witness=True)

    Decorator form (returns the planner unchanged)::

        @register_scheme("topo", batched=plan_topo_batch, topology="tree")
        def plan_topo(net, params): ...

    ``replace=True`` allows overwriting an existing entry (tests, plugin
    reload); otherwise double registration raises ValueError.
    """
    def _register(fn: ScalarPlanner) -> SchemeSpec:
        if name in _REGISTRY and not replace:
            raise ValueError(f"scheme {name!r} is already registered; "
                             f"pass replace=True to overwrite")
        spec = SchemeSpec(name=name, scalar=fn, batched=batched, jax=jax,
                          accepts_witness=accepts_witness,
                          accepts_profile=accepts_profile,
                          topology=topology, description=description)
        _REGISTRY[name] = spec
        return spec

    if scalar is None:
        def _decorator(fn: ScalarPlanner) -> ScalarPlanner:
            _register(fn)
            return fn
        return _decorator
    return _register(scalar)


def unregister_scheme(name: str) -> None:
    """Remove a scheme from the registry (tests / plugin teardown)."""
    _REGISTRY.pop(get_scheme(name).name)


def get_scheme(name: str) -> SchemeSpec:
    """Resolve a scheme name, with an error that lists what is registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r}; registered schemes: "
                         f"{sorted(_REGISTRY)}") from None


def schemes() -> Tuple[SchemeSpec, ...]:
    """All registered specs, in registration order."""
    return tuple(_REGISTRY.values())


def scheme_names(batched: Optional[bool] = None,
                 topology: Optional[str] = None,
                 jax: Optional[bool] = None) -> Tuple[str, ...]:
    """Registered scheme names in registration order, optionally filtered
    by capability: ``batched=True`` keeps schemes with a vectorized
    planner, ``batched=False`` the declared scalar-only ones; ``jax=True``
    keeps schemes with a jit-compiled planner *available in this
    environment* (always empty when jax is not importable);
    ``topology="star"|"tree"`` filters by produced structure."""
    out = []
    for spec in _REGISTRY.values():
        if batched is not None and (spec.batched is not None) != batched:
            continue
        if jax is not None and (spec.jax is not None) != jax:
            continue
        if topology is not None and spec.topology != topology:
            continue
        out.append(spec.name)
    return tuple(out)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

_warned_scalar_fallback: set = set()
_warned_jax_fallback: set = set()


def _warn_scalar_fallback(scheme: str, entry: str) -> None:
    """One warning per scheme per process — not one per call — when the
    batched engine was requested for a scheme registered without one."""
    if scheme not in _warned_scalar_fallback:
        _warned_scalar_fallback.add(scheme)
        warnings.warn(
            f"{entry}(engine='batched'): no batched planner registered for "
            f"{scheme!r} (the registry declares batched=None); falling back "
            f"to the scalar planner for all networks", RuntimeWarning,
            stacklevel=4)


def _warn_jax_fallback(scheme: str, entry: str, fallback: str) -> None:
    """One warning per scheme per process when the jax engine was requested
    for a scheme without a JAX port (or with jax absent from the env)."""
    if scheme not in _warned_jax_fallback:
        _warned_jax_fallback.add(scheme)
        why = ("the scheme declares no JAX planner" if HAS_JAX
               else "jax is not importable in this environment")
        warnings.warn(
            f"{entry}(engine='jax'): no JAX planner available for "
            f"{scheme!r} ({why}); falling back to the {fallback} engine",
            RuntimeWarning, stacklevel=4)


def _resolve_engine(spec: SchemeSpec, engine: str, entry: str) -> str:
    """Map a requested engine onto what the registry can actually run.

    ``"auto"`` never resolves to jax — the NumPy planners are the oracle
    and the CPU default; the jit tier is opt-in per call.  Explicit
    requests that cannot be honored warn once per scheme and degrade along
    jax -> batched -> scalar.
    """
    if engine == "jax":
        if spec.jax is not None:
            return "jax"
        fallback = "batched" if spec.batched is not None else "scalar"
        _warn_jax_fallback(spec.name, entry, fallback)
        return fallback
    if engine == "batched" and spec.batched is None:
        _warn_scalar_fallback(spec.name, entry)
        return "scalar"
    if engine == "auto":
        return "batched" if spec.batched is not None else "scalar"
    return engine


def _planner_kwargs(spec: SchemeSpec, witness: str, kwargs: dict) -> dict:
    """Forward ``witness`` to exactly the schemes that declared it; other
    kwargs pass through verbatim (the planner rejects what it can't take)."""
    kw = dict(kwargs)
    if spec.accepts_witness:
        kw["witness"] = witness
    return kw


def _pstage(profile, name: str):
    """Stage-timing context: ``profile`` is any PlannerProfile-shaped
    object (``stage``/``count``/``note``, see ``repro.obs.profile`` — the
    contract is duck-typed so the planning core stays import-free of the
    observability package), or None for the zero-overhead default."""
    if profile is None:
        return contextlib.nullcontext()
    return profile.stage(name)


def _check_engine(engine: str) -> None:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")


def plan(net: OverlayNetwork, params: CodeParams, scheme: str,
         engine: str = "auto", witness: str = "exact",
         profile=None, **kwargs) -> RepairPlan:
    """Plan one regeneration of ``net`` with ``scheme``.

    ``engine="auto"`` (default) runs the scalar planner — the correctness
    oracle, and the cheapest engine for a single network.  ``"batched"``
    and ``"jax"`` route through the vectorized planners as a B=1 batch
    (falling back along jax -> batched -> scalar, with a once-per-scheme
    RuntimeWarning, when the registry declares no such engine for the
    scheme).  ``witness`` selects the traffic-minimal
    witness engine and reaches exactly the schemes that declared
    ``accepts_witness``; ``profile`` (optional, a
    ``repro.obs.profile.PlannerProfile``-shaped object) records the call
    shape and wall time — planners that declared ``accepts_profile``
    additionally time their internal stages; extra ``**kwargs`` (e.g.
    ``beta_max=`` for shah, ``region=`` for fr/ftr) are forwarded
    verbatim.  Profiling never changes what is planned.
    """
    _check_engine(engine)
    spec = get_scheme(scheme)
    kw = _planner_kwargs(spec, witness, kwargs)
    resolved = "scalar" if engine == "auto" else \
        _resolve_engine(spec, engine, "plan")
    if profile is not None:
        profile.note(scheme=spec.name, batch=1, engine=resolved)
    if resolved in ("batched", "jax"):
        planner = spec.batched if resolved == "batched" else spec.jax
        if resolved == "batched" and spec.accepts_profile \
                and profile is not None:
            kw["profile"] = profile
        with _pstage(profile, "total"):
            res = planner(caps_tensor([net]), params, **kw)
        return plans_from_batch(res, params)[0]
    with _pstage(profile, "total"):
        return spec.scalar(net, params, **kw)


def plan_many(nets: Union[np.ndarray, Sequence[OverlayNetwork]],
              params: CodeParams, scheme: str, engine: str = "auto",
              witness: str = "exact", profile=None,
              **kwargs) -> BatchPlanResult:
    """Plan one scheme across a batch of networks.

    ``nets`` is either a ``(B, d+1, d+1)`` capacity tensor (see
    :func:`repro.core.caps_tensor`) or a sequence of
    :class:`OverlayNetwork`.  ``engine="auto"`` (default) uses the batched
    planner when the registry has one and the scalar loop otherwise —
    silently, because the fallback is *declared*; ``engine="batched"``
    additionally warns once per scheme when it has to fall back;
    ``engine="jax"`` routes through the jit-compiled tier for schemes that
    declared one and falls back batched-then-scalar (once-per-scheme
    RuntimeWarning); ``engine="scalar"`` always runs the per-network
    oracle.  ``"auto"`` never resolves to jax.  ``profile`` (optional,
    ``repro.obs.profile.PlannerProfile``-shaped) records batch shape,
    resolved engine and wall time, plus per-stage timings for schemes that
    declared ``accepts_profile`` (fr/ftr: bisection, candidate search,
    witness extraction...) — without changing what is planned.

    Mixed fan-outs (ragged d): when ``nets`` is a sequence of overlays
    whose ``d`` differ, the batch is bucketed by ``d``, each bucket
    planned in one engine call against ``dataclasses.replace(params,
    d=...)`` (same n/k/M/alpha — the code is fixed, the helper count is
    per-failure), and reassembled in input order.  The packed arrays are
    padded to the widest fan-out — row ``b`` of ``betas``/``parents`` is
    meaningful up to that overlay's own ``d`` and zero beyond — and the
    per-network :class:`RepairPlan` objects (each carrying its true ``d``
    via ``plan.params``) always ride along in ``plans``.

    The result's ``engine`` field reports which path actually planned the
    batch; on the scalar path the original :class:`RepairPlan` objects ride
    along in ``plans`` and ``plans_from_batch`` returns them verbatim.
    """
    _check_engine(engine)
    spec = get_scheme(scheme)
    is_tensor = isinstance(nets, np.ndarray)
    if not is_tensor:
        nets = list(nets)
        ds = {n.d for n in nets}
        if len(ds) > 1:
            return _plan_ragged(nets, params, scheme, engine=engine,
                                witness=witness, profile=profile, **kwargs)
    kw = _planner_kwargs(spec, witness, kwargs)
    resolved = _resolve_engine(spec, engine, "plan_many")
    if profile is not None:
        profile.note(scheme=spec.name,
                     batch=int(nets.shape[0]) if is_tensor else len(nets),
                     d=params.d, engine=resolved,
                     fallback=engine not in ("auto", resolved))
    if resolved in ("batched", "jax"):
        planner = spec.batched if resolved == "batched" else spec.jax
        caps = nets if is_tensor else caps_tensor(nets)
        if resolved == "batched" and spec.accepts_profile \
                and profile is not None:
            kw["profile"] = profile
        with _pstage(profile, "total"):
            return planner(caps, params, **kw)
    net_list = ([OverlayNetwork(c.tolist()) for c in nets] if is_tensor
                else list(nets))
    with _pstage(profile, "total"):
        plans = [spec.scalar(n, params, **kw) for n in net_list]
    return _batch_from_plans(spec, plans, params)


def _plan_ragged(nets: List[OverlayNetwork], params: CodeParams, scheme: str,
                 engine: str, witness: str, profile,
                 **kwargs) -> BatchPlanResult:
    """Mixed fan-out dispatch: bucket by ``d``, one engine call per bucket,
    reassemble in input order padded to the widest ``d``.

    Each bucket is planned against ``dataclasses.replace(params, d=d_b)``
    — this keeps (n, k, M, alpha) and re-runs parameter validation, so an
    overlay too small for the code (d < k) fails loudly here rather than
    producing a nonsense plan.  Per-bucket results are identical to what a
    single-d :func:`plan_many` call over that sub-batch returns (the
    bucket path *is* that call), so engine guarantees — batched bitwise
    vs scalar, jax within documented tolerance — carry over row by row.
    """
    d_max = max(n.d for n in nets)
    buckets: Dict[int, List[int]] = {}
    for i, n in enumerate(nets):
        buckets.setdefault(n.d, []).append(i)
    if profile is not None:
        profile.note(scheme=scheme, batch=len(nets), ragged=True,
                     d_buckets=sorted(buckets))
    B = len(nets)
    times = np.full(B, np.inf)
    traffic = np.full(B, np.inf)
    betas = np.zeros((B, d_max))
    parents = np.zeros((B, d_max + 1), dtype=np.int64)
    lbs = np.full(B, np.nan)
    plans: List[Optional[RepairPlan]] = [None] * B
    engines = set()
    for db in sorted(buckets):
        idx = buckets[db]
        pb = params if db == params.d else dataclasses.replace(params, d=db)
        sub = plan_many([nets[i] for i in idx], pb, scheme, engine=engine,
                        witness=witness, profile=profile, **kwargs)
        engines.add(sub.engine)
        times[idx] = sub.times
        traffic[idx] = sub.traffic
        betas[np.asarray(idx)[:, None], np.arange(db)[None, :]] = sub.betas
        parents[np.asarray(idx)[:, None],
                np.arange(db + 1)[None, :]] = sub.parents
        if sub.lower_bounds is not None:
            lbs[idx] = sub.lower_bounds
        for i, p in zip(idx, plans_from_batch(sub, pb)):
            plans[i] = p
    return BatchPlanResult(
        scheme, times, traffic, betas, parents,
        lower_bounds=None if np.isnan(lbs).all() else lbs,
        engine=engines.pop() if len(engines) == 1 else "mixed",
        plans=plans)


def _batch_from_plans(spec: SchemeSpec, plans: List[RepairPlan],
                      params: CodeParams) -> BatchPlanResult:
    """Pack scalar plans into a BatchPlanResult (the scalar-fallback path)."""
    d = params.d
    B = len(plans)
    parents = np.zeros((B, d + 1), dtype=np.int64)
    betas = np.zeros((B, d))
    lbs = np.full(B, np.nan)
    for b, p in enumerate(plans):
        for u in range(1, d + 1):
            parents[b, u] = p.parent[u]
        betas[b] = p.betas
        if p.lower_bound is not None:
            lbs[b] = p.lower_bound
    times = np.array([p.time for p in plans], dtype=np.float64)
    traffic = np.array([p.total_traffic for p in plans], dtype=np.float64)
    return BatchPlanResult(spec.name, times, traffic, betas, parents,
                           lower_bounds=None if np.isnan(lbs).all() else lbs,
                           engine="scalar", plans=plans)


# ---------------------------------------------------------------------------
# Built-in schemes (the paper's family)
# ---------------------------------------------------------------------------

register_scheme("star", plan_star, batched=plan_star_batch,
                jax=_lazy_jax("plan_star_jax"), topology="star",
                description="conventional uniform-beta star [3] (baseline)")
register_scheme("fr", plan_fr, batched=plan_fr_batch,
                jax=_lazy_jax("plan_fr_jax"), accepts_witness=True,
                accepts_profile=True, topology="star",
                description="Flexible Regeneration on the star (Section III)")
register_scheme("tr", plan_tr, batched=plan_tr_batch,
                jax=_lazy_jax("plan_tr_jax"), topology="tree",
                description="tree topology, uniform traffic (Algorithm 1)")
register_scheme("ftr", plan_ftr, batched=plan_ftr_batch,
                jax=_lazy_jax("plan_ftr_jax"), accepts_witness=True,
                accepts_profile=True, topology="tree",
                description="flexible traffic on a searched tree (Alg. 2)")
register_scheme("shah", plan_shah, batched=plan_shah_batch, topology="star",
                description="the (beta_max, gamma) scheme of Shah et al. [6]")
register_scheme("rctree", plan_rctree, batched=None, topology="tree",
                description="RCTREE [7], the MDS-violating prior scheme "
                            "(scalar only, declared)")


# ---------------------------------------------------------------------------
# Deprecation shims: the old dispatch tables, backed by the registry
# ---------------------------------------------------------------------------

_deprecation_warned: set = set()


def warn_deprecated(old: str, new: str) -> None:
    """One DeprecationWarning per legacy name per process."""
    if old not in _deprecation_warned:
        _deprecation_warned.add(old)
        warnings.warn(
            f"repro.core.{old} is deprecated; use repro.core.api.{new} "
            f"(the capability-aware scheme registry)", DeprecationWarning,
            stacklevel=4)


class _DeprecatedSchemeMap(Mapping):
    """Read-only live view of the registry behind a legacy dict name.

    Stays in sync with registrations (a newly registered scheme shows up
    immediately) and warns once per process on first use.
    """

    def __init__(self, name: str, replacement: str,
                 view: Callable[[], Dict[str, Callable]]):
        self._name = name
        self._replacement = replacement
        self._view = view

    def _touch(self) -> None:
        warn_deprecated(self._name, self._replacement)

    def __getitem__(self, key: str) -> Callable:
        self._touch()
        return self._view()[key]

    def __iter__(self) -> Iterator[str]:
        self._touch()
        return iter(self._view())

    def __len__(self) -> int:
        return len(self._view())

    def __repr__(self) -> str:  # no warning: repr is for debuggers
        return f"<deprecated {self._name} -> api.{self._replacement}: " \
               f"{sorted(self._view())}>"


SCHEMES = _DeprecatedSchemeMap(
    "SCHEMES", "plan() / get_scheme()",
    lambda: {name: spec.scalar for name, spec in _REGISTRY.items()})

BATCHED_SCHEMES = _DeprecatedSchemeMap(
    "BATCHED_SCHEMES", "plan_many() / get_scheme()",
    lambda: {name: spec.batched for name, spec in _REGISTRY.items()
             if spec.batched is not None})
