"""Jit-compiled JAX backend for the batched planning engine (ROADMAP item 2).

The NumPy batched engine (``repro.core.batched``) advances all Monte-Carlo
lanes in lockstep but still pays Python dispatch per oracle call — ~240
``waterfill_batch`` invocations per FTR batch, each a handful of small
ufuncs.  This module re-expresses the same planners as jit-compiled JAX
programs so the *entire* plan — star bisection, Theorem-1 feasibility
(sort + cumsum), the water-fill oracle, FTR's candidate stage and pivot
local search, and the level-cut min-traffic witness — compiles to one XLA
executable per (batch, d, k) shape:

* every bisection runs a fixed trip count (``lax.fori_loop`` with per-lane
  iteration budgets masked in, ``lax.while_loop`` only where the NumPy
  engine also loops data-dependently: hi-doubling, water-fill rounds,
  probe waves);
* per-lane Python state (the NumPy engine's mode machines) becomes masked
  lanes: every lane issues every oracle query, with ``jnp.where`` keeping
  non-participating lanes at a benign t=1.0 probe whose answer is ignored;
* float64 is enabled via the scoped ``jax.experimental.enable_x64``
  context around each planner call (never the global flag, so importing
  this module cannot perturb float32-default JAX code elsewhere in the
  process).

The NumPy planners remain the oracle: decision sequences (incumbent
pruning, duplicate skips, pivot accept order, tie-breaks) are replicated
operation for operation, so jax plans match the scalar/batched engines to
bisection precision.  Bitwise equality is NOT guaranteed — XLA may fuse or
reorder float reductions (matmul accumulation in the water-fill, cumsum in
the sigma check), which can flip an oracle answer exactly at a feasibility
boundary; both engines still bracket the same optimum, so times, betas and
traffic agree within ~1e-9 relative (the tolerance
``benchmarks/check_engine_parity.py`` and ``tests/test_jax_engine.py``
enforce; tree choices (parents) are asserted equal on the seeded parity
instances).  Known scalar-oracle departures, all documented here:

* ``witness="lp"`` is rejected (scipy cannot run inside jit) — use the
  batched/scalar engines for the LP witness oracle;
* the level-cut witness cannot raise on an infeasible live lane the way
  ``witness.min_level_batch`` does (no exceptions inside jit); callers get
  the same clamped-at-zero level instead.  The planners only evaluate the
  witness at a certified-feasible time, so the guard is unreachable on the
  planner path anyway.

Batch shapes are padded to the next power of two (lanes are provably
independent in every kernel — the water-fill's freeze rounds and all
``.any()``-driven loops are per-lane masked — so padding never changes real
lanes' results) to keep recompilation logarithmic in the number of distinct
batch sizes a fleet run produces.

Performance, measured honestly (1-core CPU container, fr/ftr at the
BENCH_planning profile config — see the ``engine_jax`` section of
BENCH_planning.json for the numbers of record): eliminating Python
dispatch does NOT make this tier faster than the NumPy engine here.  The
XLA per-row cost of the water-fill oracle is ~3.5x NumPy's SIMD row cost
with no fixed overhead to amortize, and a lockstep jit program cannot
compact converged lanes out of the batch the way the NumPy engine's mode
machines do, so ftr typically runs ~2-10x *slower* per plan on this
hardware (fr is roughly at parity at moderate batch sizes).  Variants
that were tried and measured worse on CPU, kept out on purpose:
speculative 2^L-way bisection (widens every oracle row 2^L-1x — loses
whenever the oracle is row-bound, which it is here), and trace-time
unrolling of the water-fill rounds in place of ``lax.while_loop`` (XLA
has no early exit, so all d rounds always run: 2.5-8x slower and up to
~97 s compile at d=19).  The value of this tier on CPU is the
parity-guarded portability of the planners to accelerator backends
(one ``jax.jit`` away from GPU/TPU, where lane width is ~free), not a
CPU speedup.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from .batched import BatchPlanResult, _star_parents
from .ftr import (EVAL_ITERS as _EVAL_ITERS, FINAL_ITERS as _FINAL_ITERS,
                  LOCAL_SEARCH_ALTS as _MAX_ALTS,
                  LOCAL_SEARCH_ROUNDS as _MAX_ROUNDS,
                  PROBE_SLACK as _PROBE_SLACK, REFINE_ITERS as _REFINE_ITERS)
from .lp import BISECT_ITERS as _STAR_ITERS
from .params import CodeParams
from .regions import FeasibleRegion, heuristic_region, msr_region

__all__ = ["plan_fr_jax", "plan_ftr_jax", "plan_star_jax", "plan_tr_jax"]


def _region_for(params: CodeParams,
                region: Optional[FeasibleRegion]) -> FeasibleRegion:
    if region is None:
        return msr_region(params) if params.is_msr else heuristic_region(params)
    return region


def _check_witness(witness: str) -> None:
    if witness != "exact":
        raise ValueError(
            f"engine='jax' supports witness='exact' only (got {witness!r}); "
            f"use engine='batched' or 'scalar' for the LP witness oracle")


def _pad_pow2(B: int) -> int:
    """Next power of two >= B: pad lanes are benign and sliced away, and the
    jit cache stays logarithmic in the number of distinct fleet batch sizes."""
    return 1 << max(0, int(B - 1).bit_length())


def _pad_caps(caps: np.ndarray) -> np.ndarray:
    """Pad the batch axis to a power of two with all-ones overlays (valid,
    always-feasible networks; every kernel is lane-independent)."""
    B, D1, _ = caps.shape
    P = _pad_pow2(B)
    if P == B:
        return caps
    pad = np.ones((P - B, D1, D1))
    idx = np.arange(D1)
    pad[:, idx, idx] = 0.0
    return np.concatenate([caps, pad], axis=0)


# ---------------------------------------------------------------------------
# Shared jit-side primitives (traced inside the planner kernels)
# ---------------------------------------------------------------------------

def _subtree_masks(parents):
    """JAX port of ``batched.subtree_masks``: pointer-doubling transitive
    closure of the parent relation.  parents (P, D1) int -> (P, D1, d)."""
    P, D1 = parents.shape
    node = jnp.arange(D1)
    C = jnp.zeros((P, D1, D1))
    C = C.at[:, node, node].set(1.0)
    C = C.at[jnp.arange(P)[:, None], node[None, 1:], parents[:, 1:]].set(1.0)
    steps = 1
    while steps < D1:               # static python loop: log2(D1) squarings
        C = ((C @ C) > 0).astype(C.dtype)
        steps *= 2
    return jnp.swapaxes(C, 1, 2)[:, :, 1:]


def _edge_caps(caps, parents):
    """edge_caps[p, u-1] = c(u, parent(u)) for each lane's full tree."""
    P, D1 = parents.shape
    return caps[jnp.arange(P)[:, None], jnp.arange(1, D1)[None, :],
                parents[:, 1:]]


def _nest(inc):
    """Laminar nesting relation: boolean Gram matrix (see batched._nest_of)."""
    return (inc @ jnp.swapaxes(inc, 1, 2)) > 0


def _sigma_feasible(beta, x, tol):
    """Theorem-1 region check: sigma_j(beta) >= x_j - tol for all j."""
    d = beta.shape[-1]
    k = x.shape[0]
    sig = jnp.cumsum(jnp.sort(beta, axis=-1), axis=-1)[..., d - k:]
    return jnp.all(sig >= x - tol, axis=-1)


def _waterfill(inc, bnd, alpha, chain):
    """Lockstep leximin water-fill, mirroring ``batched.waterfill_batch``
    round for round (chain-minimal saturated sets freeze together; the
    terminal round alpha-fills all still-active coordinates).

    The loop is data-dependent (every round freezes at least one active
    coordinate per lane, so it runs at most d+1 rounds); a ``while_loop``
    keeps the average ~3-5 rounds instead of always paying d — measured
    2.5-8x faster than a trace-time unroll of d rounds on CPU-XLA."""
    P, S, d = inc.shape
    athr = alpha - 1e-15

    def body(st):
        v, active, _ = st
        X = jnp.stack([active, v * (1.0 - active)], axis=-1)     # (P, d, 2)
        Y = inc @ X                      # (active counts, frozen sums)
        na = Y[..., 0]
        cand = jnp.where(na == 0, jnp.inf,
                         (bnd - Y[..., 1]) / jnp.maximum(na, 1.0))
        freezable = cand < athr
        any_f = freezable.any()
        chmin = jnp.min(jnp.where(chain, cand[:, None, :], jnp.inf), axis=2)
        setfreeze = freezable & (cand <= chmin)
        any_set = setfreeze.any(axis=1)
        lamx = jnp.min(jnp.where(setfreeze[:, :, None] & (inc > 0),
                                 cand[:, :, None], jnp.inf), axis=1)
        lamx = jnp.maximum(lamx, 0.0)
        fin = lamx < jnp.inf
        mfrz = fin | ~any_set[:, None]
        lvl = jnp.where(fin, lamx, alpha)
        v_frz = jnp.where(mfrz & (active > 0), lvl, v)
        a_frz = active * (1.0 - mfrz)
        v_term = jnp.where(active > 0, alpha, v)
        v_new = jnp.where(any_f, v_frz, v_term)
        a_new = jnp.where(any_f, a_frz, jnp.zeros_like(active))
        done = ~any_f | ~(a_new > 0).any()
        return v_new, a_new, done

    init = (jnp.zeros((P, d)), jnp.ones((P, d)), jnp.asarray(False))
    v, _, _ = lax.while_loop(lambda st: ~st[2], body, init)
    return v


def _tree_feasible(t, mask, ec, x, alpha, chain):
    """``batched.tree_feasible_batch``: binding edges (t*c < alpha - 1e-12)
    bound their subtree sums; the water-fill point is checked against the
    region thresholds at the scalar oracle's 1e-9 tolerance."""
    bounds = t[:, None] * ec
    bnd = jnp.where(bounds < alpha - 1e-12, bounds, jnp.inf)
    wf = _waterfill(mask[:, 1:, :], bnd, alpha, chain)
    return _sigma_feasible(wf, x, 1e-9), wf


def _min_level(ub, x):
    """Exact minimal level cut (``witness.min_level_batch`` minus the
    infeasible-lane raise, which cannot exist inside jit)."""
    B, d = ub.shape
    k = x.shape[0]
    s = jnp.sort(ub, axis=1)
    S = jnp.concatenate([jnp.zeros((B, 1)), jnp.cumsum(s, axis=1)], axis=1)
    p = jnp.arange(d)
    m = d - k + jnp.arange(1, k + 1)
    denom = (m[None, :, None] - p[None, None, :]).astype(s.dtype)
    cand = (x[None, :, None] - S[:, None, :d]) / denom
    cand = jnp.where(denom > 0, cand, -jnp.inf)
    return jnp.maximum(jnp.max(cand, axis=(1, 2)), 0.0)


def _level_cut(ub, x):
    return jnp.minimum(ub, _min_level(ub, x)[:, None])


def _star_time(flows, direct):
    return jnp.max(jnp.where(direct > 0, flows / direct, jnp.inf), axis=1)


# ---------------------------------------------------------------------------
# STAR / FR
# ---------------------------------------------------------------------------

@jax.jit
def _star_kernel(direct, beta, alpha):
    B, d = direct.shape
    f = jnp.minimum(beta, alpha)
    flows = jnp.full((B, d), f)
    return (_star_time(flows, direct), flows.sum(axis=1),
            jnp.full((B, d), beta))


def _star_optimal_time(direct, x, alpha, lanes):
    """``batched.minmax_time_star_batch``: bisection on the coordinate-wise
    max point, 1e-12 region tolerance, hi-doubling giving up past 1e18."""
    B, d = direct.shape

    def feas(t):
        bh = jnp.minimum(t[:, None] * direct, alpha)
        return _sigma_feasible(bh, x, 1e-12)

    hi = jnp.ones(B)
    ok = feas(hi) | ~lanes

    def dbody(st):
        hi, ok = st
        hi = jnp.where(ok, hi, hi * 2.0)
        ok = ok | (hi > 1e18) | feas(hi)
        return hi, ok

    hi, _ = lax.while_loop(lambda st: ~st[1].all(), dbody, (hi, ok))
    dead = lanes & (hi > 1e18)
    lo = jnp.zeros(B)

    def bbody(_, st):
        lo, hi = st
        mid = 0.5 * (lo + hi)
        f = feas(mid)
        return jnp.where(f, lo, mid), jnp.where(f, mid, hi)

    lo, hi = lax.fori_loop(0, _STAR_ITERS, bbody, (lo, hi))
    return jnp.where(dead, jnp.inf, hi)


@functools.partial(jax.jit, static_argnames=("is_msr", "minimize_traffic"))
def _fr_kernel(direct, x, alpha, M, is_msr, minimize_traffic):
    B, d = direct.shape
    k = x.shape[0]
    betas = jnp.zeros((B, d))
    lb = jnp.zeros(B)
    closed = jnp.zeros(B, dtype=bool)
    if is_msr:
        # MSR closed form (star.fr_closed_form_msr) on all-positive lanes
        closed = (direct > 0).all(axis=1)
        m = d - k + 1
        safe = jnp.where(closed[:, None], direct, 1.0)
        order = jnp.argsort(safe, axis=1, stable=True)
        csort = jnp.take_along_axis(safe, order, axis=1)
        denom = csort[:, :m].sum(axis=1)
        rank = jnp.arange(d)[None, :]
        bsort = (jnp.where(rank < m, csort, csort[:, m - 1:m])
                 * M / (k * denom[:, None]))
        inv = jnp.argsort(order, axis=1, stable=True)
        cb = jnp.take_along_axis(bsort, inv, axis=1)
        ct = (cb / safe).max(axis=1)
        betas = jnp.where(closed[:, None], cb, betas)
        lb = jnp.where(closed, ct, lb)
    rest = ~closed
    t_rest = _star_optimal_time(direct, x, alpha, rest)
    lb = jnp.where(rest, t_rest, lb)
    live = rest & jnp.isfinite(t_rest)
    if minimize_traffic:
        ub = jnp.minimum(jnp.where(live, t_rest, 0.0)[:, None] * direct, alpha)
        wb = _level_cut(ub, x)
    else:
        wb = jnp.minimum(jnp.where(live, t_rest, 0.0)[:, None] * direct, alpha)
    betas = jnp.where(live[:, None], wb, betas)
    flows = jnp.minimum(betas, alpha)
    times = jnp.maximum(_star_time(flows, direct), 0.0)
    bad = ~jnp.isfinite(lb)
    times = jnp.where(bad, jnp.inf, times)
    traffic = jnp.where(bad, jnp.inf, flows.sum(axis=1))
    return times, traffic, betas, lb


# ---------------------------------------------------------------------------
# TR — Algorithm 1 (incremental greedy, lockstep)
# ---------------------------------------------------------------------------

def _tr_greedy(caps, beta, alpha):
    """The d-step greedy of ``batched.plan_tr_batch`` with the identical
    lexicographic (t, -c(v,u), v, u) candidate selection."""
    B, D1, _ = caps.shape
    d = D1 - 1
    bidx = jnp.arange(B)
    new_flow = jnp.minimum(beta, alpha)
    new_edge_t = jnp.where(caps > 0, new_flow / caps, jnp.inf)

    def body(_, st):
        parent, attached, anc, size, edge_c = st
        att_e = attached.at[:, 0].set(False)
        f_now = jnp.minimum(size * beta, alpha)
        f_inc = jnp.minimum((size + 1.0) * beta, alpha)
        h = jnp.where(att_e, jnp.where(edge_c > 0, f_now / edge_c, jnp.inf),
                      -jnp.inf)
        g = jnp.where(att_e, jnp.where(edge_c > 0, f_inc / edge_c, jnp.inf),
                      -jnp.inf)
        val = jnp.where(anc, g[:, :, None], h[:, :, None])
        T_path = jnp.maximum(val.max(axis=1), 0.0)
        cand_t = jnp.maximum(new_edge_t, T_path[:, None, :])
        valid = (~attached)[:, :, None] & attached[:, None, :]
        cand_t = jnp.where(valid, cand_t, jnp.inf)
        tmin = cand_t.min(axis=(1, 2))
        is_t = valid & (cand_t == tmin[:, None, None])
        cgrid = jnp.where(is_t, caps, -jnp.inf)
        cmax = cgrid.max(axis=(1, 2))
        sel = is_t & (cgrid == cmax[:, None, None])
        choice = jnp.argmax(sel.reshape(B, -1), axis=1)
        v_sel = choice // D1
        u_sel = choice % D1
        parent = parent.at[bidx, v_sel].set(u_sel.astype(parent.dtype))
        attached = attached.at[bidx, v_sel].set(True)
        edge_c = edge_c.at[bidx, v_sel].set(caps[bidx, v_sel, u_sel])
        size = size + anc[bidx, :, u_sel]
        size = size.at[bidx, v_sel].set(1.0)
        anc = anc.at[bidx, :, v_sel].set(anc[bidx, :, u_sel])
        anc = anc.at[bidx, v_sel, v_sel].set(True)
        return parent, attached, anc, size, edge_c

    init = (jnp.zeros((B, D1), dtype=jnp.int32),
            jnp.zeros((B, D1), dtype=bool).at[:, 0].set(True),
            jnp.zeros((B, D1, D1), dtype=bool),
            jnp.zeros((B, D1)),
            jnp.zeros((B, D1)))
    parent, _, _, size, edge_c = lax.fori_loop(0, d, body, init)
    return parent, size, edge_c


@jax.jit
def _tr_kernel(caps, beta, alpha):
    parent, size, edge_c = _tr_greedy(caps, beta, alpha)
    flows = jnp.minimum(size[:, 1:] * beta, alpha)
    et = jnp.where(edge_c[:, 1:] > 0, flows / edge_c[:, 1:], jnp.inf)
    return et.max(axis=1), flows.sum(axis=1), parent


# ---------------------------------------------------------------------------
# FTR — Algorithm 2 (candidate population + pivot local search), lockstep
# ---------------------------------------------------------------------------

def _ftr_candidates(caps, tr_parents):
    """``batched._ftr_candidates``: one core-growth pass (prefix property),
    then every core size i = 0..d as a candidate, plus the TR tree."""
    B, D1, _ = caps.shape
    d = D1 - 1
    bidx = jnp.arange(B)

    def gbody(step, st):
        in_core, core_pos, parfull = st
        cuv = jnp.where(~in_core[:, :, None] & in_core[:, None, :], caps,
                        -jnp.inf)
        cuv = cuv.at[:, 0, :].set(-jnp.inf)
        rowbest = cuv.max(axis=2)
        u_sel = jnp.argmax(rowbest, axis=1)
        best = rowbest[bidx, u_sel]
        pos = jnp.where(cuv[bidx, u_sel, :] == best[:, None], core_pos,
                        D1 + 2)
        v_sel = jnp.argmin(pos, axis=1)
        parfull = parfull.at[bidx, u_sel].set(v_sel.astype(parfull.dtype))
        in_core = in_core.at[bidx, u_sel].set(True)
        core_pos = core_pos.at[bidx, u_sel].set(
            (step + 1).astype(core_pos.dtype))
        return in_core, core_pos, parfull

    init = (jnp.zeros((B, D1), dtype=bool).at[:, 0].set(True),
            jnp.full((B, D1), D1 + 1, dtype=jnp.int32).at[:, 0].set(0),
            jnp.zeros((B, D1), dtype=jnp.int32))
    _, core_pos, parfull = lax.fori_loop(0, d, gbody, init)

    ii = jnp.arange(d + 1)[None, :, None]                     # (1, d+1, 1)
    mask_core = core_pos[:, None, :] <= ii                    # (B, d+1, D1)
    cu = jnp.where(mask_core[:, :, None, :], caps[:, None, :, :], -jnp.inf)
    mx = cu.max(axis=3)
    posg = jnp.where(cu == mx[..., None], core_pos[:, None, None, :], D1 + 2)
    vbest = jnp.argmin(posg, axis=3).astype(jnp.int32)        # (B, d+1, u)
    par = jnp.where(mask_core, parfull[:, None, :], vbest)
    par = par.at[:, :, 0].set(0)
    return jnp.concatenate([par, tr_parents[:, None, :].astype(par.dtype)],
                           axis=1)                            # (B, d+2, D1)


def _candidate_times(caps, cands, x, alpha):
    """Per-candidate optimal times with the scalar planner's incumbent
    pruning, lockstep over candidates: candidate c is probed at the lane's
    incumbent (refine 28 iters on accept, inf on reject); lanes with no
    finite incumbent run the full 40-iter solve.  Duplicate and
    zero-capacity candidates are skipped exactly as the NumPy engine's."""
    B, C, D1 = cands.shape
    d = D1 - 1
    flat = cands.reshape(B * C, D1)
    mask_all = _subtree_masks(flat)
    lane_of = jnp.repeat(jnp.arange(B), C)
    ec_all = caps[lane_of[:, None], jnp.arange(1, D1)[None, :], flat[:, 1:]]
    chain_all = _nest(mask_all[:, 1:, :])
    eq = (cands[:, :, None, :] == cands[:, None, :, :]).all(axis=-1)
    dup = (eq & jnp.tril(jnp.ones((C, C), dtype=bool), -1)[None]).any(axis=2)
    ec_ok = (ec_all > 0).all(axis=1).reshape(B, C)
    hi0_all = ((alpha / jnp.where(ec_all > 0, ec_all, 1.0)).max(axis=1)
               * (1 + 1e-9) + 1e-12).reshape(B, C)
    mask_r = mask_all.reshape(B, C, D1, d)
    ec_r = ec_all.reshape(B, C, d)
    ch_r = chain_all.reshape(B, C, d, d)

    def cbody(c, st):
        t_cand, incumbent = st
        m = lax.dynamic_index_in_dim(mask_r, c, 1, keepdims=False)
        ec = lax.dynamic_index_in_dim(ec_r, c, 1, keepdims=False)
        ch = lax.dynamic_index_in_dim(ch_r, c, 1, keepdims=False)
        okl = (~lax.dynamic_index_in_dim(dup, c, 1, keepdims=False)
               & lax.dynamic_index_in_dim(ec_ok, c, 1, keepdims=False))
        hi0 = lax.dynamic_index_in_dim(hi0_all, c, 1, keepdims=False)
        has_inc = jnp.isfinite(incumbent)
        probe_lane = okl & has_inc
        full_lane = okl & ~has_inc
        pf, _ = _tree_feasible(jnp.where(probe_lane, incumbent, 1.0), m, ec,
                               x, alpha, ch)
        pf = pf & probe_lane
        hi = jnp.where(full_lane, hi0, jnp.where(pf, incumbent, 1.0))
        f0, _ = _tree_feasible(jnp.where(full_lane, hi, 1.0), m, ec, x,
                               alpha, ch)
        feasd = f0 & full_lane
        need0 = full_lane & ~feasd

        def dbody(dst):
            hi, feasd, need = dst
            hi = jnp.where(need, hi * 2.0, hi)
            over = hi >= 1e18
            f2, _ = _tree_feasible(jnp.where(need & ~over, hi, 1.0), m, ec,
                                   x, alpha, ch)
            feasd = feasd | (need & ~over & f2)
            return hi, feasd, need & ~feasd & ~over

        hi, feasd, _ = lax.while_loop(lambda dst: dst[2].any(), dbody,
                                      (hi, feasd, need0))
        solve = pf | feasd
        budget = jnp.where(full_lane, _EVAL_ITERS, _REFINE_ITERS)
        lo = jnp.zeros_like(hi)

        def bbody(i, bst):
            lo, hi = bst
            on = solve & (i < budget)
            mid = 0.5 * (lo + hi)
            f, _ = _tree_feasible(jnp.where(on, mid, 1.0), m, ec, x, alpha,
                                  ch)
            return (jnp.where(on & ~f, mid, lo), jnp.where(on & f, mid, hi))

        lo, hi = lax.fori_loop(0, _EVAL_ITERS, bbody, (lo, hi))
        t_c = jnp.where(solve, hi, jnp.inf)
        t_cand = lax.dynamic_update_index_in_dim(t_cand, t_c, c, 1)
        return t_cand, jnp.minimum(incumbent, t_c)

    t_cand, _ = lax.fori_loop(0, C, cbody,
                              (jnp.full((B, C), jnp.inf), jnp.full(B, jnp.inf)))
    return t_cand


def _tree_optimal_time(mask, ec, ch, x, alpha, iters, lanes):
    """``batched.tree_optimal_time_batch`` (lockstep, no lane compaction)."""
    B = ec.shape[0]
    valid = lanes & (ec > 0).all(axis=1)
    safe = jnp.where(ec > 0, ec, 1.0)
    hi = jnp.where(valid, (alpha / safe).max(axis=1) * (1 + 1e-9) + 1e-12,
                   jnp.inf)
    feas, _ = _tree_feasible(jnp.where(valid, hi, 1.0), mask, ec, x, alpha,
                             ch)
    feas = feas & valid
    need0 = valid & ~feas

    def dbody(dst):
        hi, feas, need = dst
        hi = jnp.where(need, hi * 2.0, hi)
        over = hi >= 1e18
        f2, _ = _tree_feasible(jnp.where(need & ~over, hi, 1.0), mask, ec,
                               x, alpha, ch)
        feas = feas | (need & ~over & f2)
        return hi, feas, valid & ~feas & ~over

    hi, feas, _ = lax.while_loop(lambda dst: dst[2].any(), dbody,
                                 (hi, feas, need0))
    live = valid & feas
    lo = jnp.zeros(B)

    def bbody(_, bst):
        lo, hi = bst
        mid = 0.5 * (lo + hi)
        f, _ = _tree_feasible(jnp.where(live, mid, 1.0), mask, ec, x, alpha,
                              ch)
        return (jnp.where(live & ~f, mid, lo), jnp.where(live & f, mid, hi))

    lo, hi = lax.fori_loop(0, iters, bbody, (lo, hi))
    return jnp.where(live, hi, jnp.inf)


def _local_search(caps, parents, t_cur, x, alpha, alive):
    """``batched._local_search_batch`` in lockstep: rounds x nodes unrolled
    to a fixed ``fori_loop`` over (round, u) steps with a per-lane
    ``running`` mask; within a step, probe waves over the node's untried
    alternatives run data-dependently (``while_loop``), first feasible
    alternative accepted, refine [0, t_cur] on accept, remaining
    alternatives replayed on the updated tree — the scalar pivot sweep's
    exact decision sequence."""
    L, D1 = parents.shape
    d = D1 - 1
    A = min(_MAX_ALTS, D1)
    lidx = jnp.arange(L)
    bm0 = _subtree_masks(parents)
    ec0 = _edge_caps(caps, parents)
    ch0 = _nest(bm0[:, 1:, :])
    root_onehot = jnp.zeros(D1).at[0].set(1.0)

    def step(s, st):
        parents, bm, ec, ch, t_cur, improved, running = st
        u = s % d + 1
        cpu = caps[:, u, :]                         # (L, D1), dynamic gather
        dsc = bm[:, u, :]                           # (L, d)
        pw = parents[:, u]
        nodes = jnp.arange(D1)[None, :]
        in_sub = jnp.concatenate([jnp.zeros((L, 1)), dsc], axis=1)
        ok = ((cpu > 0) & (nodes != u) & (nodes != pw[:, None])
              & ~(in_sub > 0))
        nok = jnp.minimum(ok.sum(axis=1), _MAX_ALTS)
        ordw = jnp.argsort(jnp.where(ok, -cpu, jnp.inf), axis=1,
                           stable=True)[:, :A]

        def wbody(wst):
            parents, bm, ec, ch, t_cur, improved, jj = wst
            aidx = jnp.arange(A)[None, :]
            validA = (aidx >= jj[:, None]) & (aidx < nok[:, None])
            palt = ordw                                        # (L, A)
            # one-edge mask update (the NumPy engine's incremental formula):
            # u's descendants keep their in-subtree ancestors and adopt the
            # new parent's ancestor chain; all other chains are untouched
            anc_v = jnp.where((palt >= 1)[:, :, None],
                              bm[lidx[:, None], :, jnp.maximum(palt - 1, 0)],
                              root_onehot[None, None, :])      # (L, A, D1)
            pmask = jnp.where(
                dsc[:, None, None, :] > 0,
                jnp.minimum(bm[:, None, :, :] * in_sub[:, None, :, None]
                            + anc_v[..., None], 1.0),
                bm[:, None, :, :])                             # (L, A, D1, d)
            newc = jnp.take_along_axis(cpu, palt, axis=1)      # (L, A)
            colu = jnp.arange(d)[None, None, :] == (u - 1)
            pec = jnp.where(colu, newc[:, :, None], ec[:, None, :])
            flatm = pmask.reshape(L * A, D1, d)
            flate = pec.reshape(L * A, d)
            flatch = _nest(flatm[:, 1:, :])
            tq = jnp.where(validA, (t_cur * _PROBE_SLACK)[:, None],
                           1.0).reshape(L * A)
            fq, _ = _tree_feasible(tq, flatm, flate, x, alpha, flatch)
            fA = fq.reshape(L, A) & validA
            acc = fA.any(axis=1)
            jstar = jnp.argmax(fA, axis=1)                 # first feasible
            vnew = jnp.take_along_axis(palt, jstar[:, None], axis=1)[:, 0]
            parents = parents.at[lidx, u].set(
                jnp.where(acc, vnew, parents[:, u]).astype(parents.dtype))
            selm = pmask[lidx, jstar]
            sele = pec[lidx, jstar]
            selch = flatch.reshape(L, A, d, d)[lidx, jstar]
            bm = jnp.where(acc[:, None, None], selm, bm)
            ec = jnp.where(acc[:, None], sele, ec)
            ch = jnp.where(acc[:, None, None], selch, ch)

            def rbody(_, rst):
                lo, hi = rst
                mid = 0.5 * (lo + hi)
                f, _ = _tree_feasible(jnp.where(acc, mid, 1.0), bm, ec, x,
                                      alpha, ch)
                return (jnp.where(acc & ~f, mid, lo),
                        jnp.where(acc & f, mid, hi))

            def do_refine(t_cur):
                _, hi = lax.fori_loop(0, _REFINE_ITERS, rbody,
                                      (jnp.zeros(L), t_cur))
                return jnp.where(acc, hi, t_cur)

            t_cur = lax.cond(acc.any(), do_refine, lambda t: t, t_cur)
            improved = improved | acc
            jj = jnp.where(acc, jstar + 1, nok)
            return parents, bm, ec, ch, t_cur, improved, jj

        jj0 = jnp.where(running, 0, nok)
        parents, bm, ec, ch, t_cur, improved, _ = lax.while_loop(
            lambda wst: (wst[6] < nok).any(), wbody,
            (parents, bm, ec, ch, t_cur, improved, jj0))
        at_end = (s % d) == (d - 1)
        running = jnp.where(at_end, running & improved, running)
        improved = jnp.where(at_end, jnp.zeros_like(improved), improved)
        return parents, bm, ec, ch, t_cur, improved, running

    init = (parents, bm0, ec0, ch0, t_cur, jnp.zeros(L, dtype=bool), alive)
    parents, _, _, _, t_cur, _, _ = lax.fori_loop(0, _MAX_ROUNDS * d, step,
                                                  init)
    return parents, t_cur


@functools.partial(jax.jit, static_argnames=("local_search",))
def _ftr_kernel(caps, x, alpha, beta_u, local_search):
    B, D1, _ = caps.shape
    d = D1 - 1
    bidx = jnp.arange(B)
    tr_parent, _, _ = _tr_greedy(caps, beta_u, alpha)
    cands = _ftr_candidates(caps, tr_parent)
    t_cand = _candidate_times(caps, cands, x, alpha)
    order = jnp.argsort(t_cand, axis=1, stable=True)
    best_t = jnp.take_along_axis(t_cand, order[:, :1], axis=1)[:, 0]
    best_par = cands[bidx, order[:, 0]]
    if local_search:
        top = order[:, :3]
        par_ls = cands[bidx[:, None], top].reshape(B * 3, D1)
        t_ls = jnp.take_along_axis(t_cand, top, axis=1).reshape(B * 3)
        caps_ls = jnp.repeat(caps, 3, axis=0)
        par_ls, t_ls = _local_search(caps_ls, par_ls, t_ls, x, alpha,
                                     jnp.isfinite(t_ls))
        par_ls = par_ls.reshape(B, 3, D1)
        t_ls = t_ls.reshape(B, 3)
        for s in range(3):                  # winner update order: s = 0,1,2
            upd = t_ls[:, s] < best_t
            best_t = jnp.where(upd, t_ls[:, s], best_t)
            best_par = jnp.where(upd[:, None], par_ls[:, s], best_par)
    mask = _subtree_masks(best_par)
    ec = _edge_caps(caps, best_par)
    ch = _nest(mask[:, 1:, :])
    solvable = jnp.isfinite(best_t)
    t_star = _tree_optimal_time(mask, ec, ch, x, alpha, _FINAL_ITERS,
                                solvable)
    _, wf = _tree_feasible(jnp.where(solvable, t_star, 1.0), mask, ec, x,
                           alpha, ch)
    betas = jnp.where(solvable[:, None], _level_cut(wf, x), 0.0)
    sub = jnp.einsum("bud,bd->bu", mask[:, 1:, :], betas)
    flows = jnp.minimum(sub, alpha)
    et = jnp.where(ec > 0, flows / ec, jnp.inf)
    times = jnp.where(solvable, et.max(axis=1), jnp.inf)
    traffic = jnp.where(solvable, flows.sum(axis=1), jnp.inf)
    return times, traffic, betas, best_par, t_star


# ---------------------------------------------------------------------------
# Public planners (the SchemeSpec.jax entries)
# ---------------------------------------------------------------------------

def plan_star_jax(caps: np.ndarray, params: CodeParams) -> BatchPlanResult:
    """Jit-compiled ``plan_star_batch``."""
    caps = np.asarray(caps, dtype=np.float64)
    B, _, _ = caps.shape
    d = params.d
    with enable_x64():
        t, tr, be = _star_kernel(jnp.asarray(_pad_caps(caps)[:, 1:, 0]),
                                 float(params.beta), float(params.alpha))
        t, tr, be = (np.asarray(a)[:B] for a in (t, tr, be))
    return BatchPlanResult("star", t, tr, be, _star_parents(B, d),
                           engine="jax")


def plan_fr_jax(caps: np.ndarray, params: CodeParams,
                region: Optional[FeasibleRegion] = None,
                minimize_traffic: bool = True,
                witness: str = "exact") -> BatchPlanResult:
    """Jit-compiled ``plan_fr_batch`` (closed form at MSR, lockstep star
    bisection + level-cut witness elsewhere)."""
    _check_witness(witness)
    region = _region_for(params, region)
    caps = np.asarray(caps, dtype=np.float64)
    B, _, _ = caps.shape
    d = params.d
    x = np.asarray(region.x, dtype=np.float64)
    with enable_x64():
        t, tr, be, lb = _fr_kernel(jnp.asarray(_pad_caps(caps)[:, 1:, 0]),
                                   jnp.asarray(x), float(params.alpha),
                                   float(params.M), is_msr=params.is_msr,
                                   minimize_traffic=bool(minimize_traffic))
        t, tr, be, lb = (np.asarray(a)[:B] for a in (t, tr, be, lb))
    return BatchPlanResult("fr", t, tr, be, _star_parents(B, d),
                           lower_bounds=lb, engine="jax")


def plan_tr_jax(caps: np.ndarray, params: CodeParams) -> BatchPlanResult:
    """Jit-compiled ``plan_tr_batch`` (Algorithm 1)."""
    caps = np.asarray(caps, dtype=np.float64)
    B, _, _ = caps.shape
    d = params.d
    with enable_x64():
        t, tr, par = _tr_kernel(jnp.asarray(_pad_caps(caps)),
                                float(params.beta), float(params.alpha))
        t, tr = np.asarray(t)[:B], np.asarray(tr)[:B]
        par = np.asarray(par)[:B].astype(np.int64)
    return BatchPlanResult("tr", t, tr, np.full((B, d), params.beta), par,
                           engine="jax")


def plan_ftr_jax(caps: np.ndarray, params: CodeParams,
                 region: Optional[FeasibleRegion] = None,
                 local_search: bool = True,
                 witness: str = "exact") -> BatchPlanResult:
    """Jit-compiled ``plan_ftr_batch`` (Algorithm 2 + pivot search + final
    50-iteration solve + level-cut witness)."""
    _check_witness(witness)
    region = _region_for(params, region)
    caps = np.asarray(caps, dtype=np.float64)
    B, _, _ = caps.shape
    x = np.asarray(region.x, dtype=np.float64)
    with enable_x64():
        t, tr, be, par, lbs = _ftr_kernel(
            jnp.asarray(_pad_caps(caps)), jnp.asarray(x),
            float(params.alpha), float(params.beta),
            local_search=bool(local_search))
        t, tr, be, lbs = (np.asarray(a)[:B] for a in (t, tr, be, lbs))
        par = np.asarray(par)[:B].astype(np.int64)
    return BatchPlanResult("ftr", t, tr, be, par, lower_bounds=lbs,
                           engine="jax")
