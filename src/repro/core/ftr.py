"""Flexible Tree-structured Regeneration (FTR, paper Section V).

Combines the tree topology (Section IV) with non-uniform per-provider
traffic (Section III).  Theorem 5 gives the sufficient MDS condition — the
same sigma_j thresholds as the star heuristic region — and for a *given*
tree the optimal time is found exactly (bisection + LP oracle,
``lp.tree_optimal_time``; cf. problem (5)-(10)).

Tree search follows Algorithm 2: for each i = 0..d, grow a max-capacity
core subtree of i links from the newcomer, attach the remaining providers
to their best position in the core, then locally improve with pivot moves
(re-attach one subtree) while the exact per-tree objective improves.  Two
extra candidate trees are evaluated — the FR star (i = 0, which Algorithm 2
already contains) and the TR tree — so FTR is never worse than FR or TR
(the paper's "promised by design" dominance, Section VI-A, made explicit).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .params import CodeParams, Edge, OverlayNetwork, RepairPlan, tree_flows
from .regions import FeasibleRegion, heuristic_region, msr_region
from . import lp
from .tree import plan_tr

# Search hyper-parameters, shared with the batched engine (repro.core.batched
# mirrors this planner decision-for-decision; importing these keeps the two
# implementations from drifting apart).
EVAL_ITERS = 40        # fresh-tree bisection depth (eval_tree)
REFINE_ITERS = 28      # incumbent-bounded bisection depth (_refine)
FINAL_ITERS = 50       # high-precision solve on the winning tree
LOCAL_SEARCH_ROUNDS = 3
LOCAL_SEARCH_ALTS = 8  # alternative parents probed per pivot node
PROBE_SLACK = 1 - 1e-7  # pivot must beat the incumbent by this factor


def _edge_caps(parent: Dict[int, int], net: OverlayNetwork) -> Dict[Edge, float]:
    return {(u, p): net.c(u, p) for u, p in parent.items()}


def eval_tree(parent: Dict[int, int], net: OverlayNetwork, params: CodeParams,
              region: FeasibleRegion, iters: int = EVAL_ITERS,
              minimize_traffic: bool = False, witness: str = "exact",
              ) -> Tuple[float, Optional[List[float]]]:
    return lp.tree_optimal_time(parent, _edge_caps(parent, net), region,
                                params.alpha, iters=iters,
                                minimize_traffic=minimize_traffic,
                                witness=witness)


def _grow_core(net: OverlayNetwork, i: int, d: int) -> List[int]:
    """Lines 3-8 of Algorithm 2: greedily add the largest-capacity cut link."""
    core = [0]
    for _ in range(i):
        best_u, best_c, best_v = None, -1.0, None
        for u in range(1, d + 1):
            if u in core:
                continue
            for v in core:
                if net.c(u, v) > best_c:
                    best_u, best_c, best_v = u, net.c(u, v), v
        if best_u is None:
            break
        core.append(best_u)
    return core


def _initial_tree(net: OverlayNetwork, core: List[int], d: int) -> Dict[int, int]:
    """Core subtree edges (each core node to its best earlier core node) plus
    lines 10-14: attach every remaining provider to its best core position."""
    parent: Dict[int, int] = {}
    placed = [0]
    for u in core[1:]:
        v = max(placed, key=lambda v: net.c(u, v))
        parent[u] = v
        placed.append(u)
    for u in range(1, d + 1):
        if u in core:
            continue
        v = max(core, key=lambda v: net.c(u, v))
        parent[u] = v
    return parent


def _descendants(parent: Dict[int, int], u: int, d: int) -> set:
    desc = set()
    for w in range(1, d + 1):
        x = w
        while x != 0:
            if x == u:
                desc.add(w)
                break
            x = parent[x]
    return desc


def _feasible_at(t: float, parent: Dict[int, int], net: OverlayNetwork,
                 params: CodeParams, region: FeasibleRegion) -> bool:
    return lp.tree_feasible_at_time(t, parent, _edge_caps(parent, net),
                                    region, params.alpha) is not None


def _refine(parent: Dict[int, int], net: OverlayNetwork, params: CodeParams,
            region: FeasibleRegion, t_ub: float,
            iters: int = REFINE_ITERS) -> float:
    """Bisect the optimal time of ``parent`` knowing it is feasible at t_ub."""
    lo, hi = 0.0, t_ub
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if _feasible_at(mid, parent, net, params, region):
            hi = mid
        else:
            lo = mid
    return hi


def _local_search(parent: Dict[int, int], net: OverlayNetwork,
                  params: CodeParams, region: FeasibleRegion, t_cur: float,
                  max_rounds: int = LOCAL_SEARCH_ROUNDS,
                  max_alts: int = LOCAL_SEARCH_ALTS,
                  ) -> Tuple[Dict[int, int], float]:
    """Pivot search with incremental evaluation: each candidate pivot is
    first probed with a single feasibility check at the incumbent time;
    bisection runs only on acceptance.  This keeps the oracle-call count
    O(pivots + log(1/eps) * improvements) rather than O(pivots * log)."""
    d = params.d
    for _ in range(max_rounds):
        improved = False
        for u in range(1, d + 1):
            desc = _descendants(parent, u, d)
            cur_p = parent[u]
            # try alternative parents in decreasing link-capacity order
            alts = sorted((v for v in range(0, d + 1)
                           if v != u and v != cur_p and v not in desc
                           and net.c(u, v) > 0),
                          key=lambda v: -net.c(u, v))[:max_alts]
            for v in alts:
                parent[u] = v
                if _feasible_at(t_cur * PROBE_SLACK, parent, net, params, region):
                    t_cur = _refine(parent, net, params, region, t_cur)
                    cur_p = v
                    improved = True
                else:
                    parent[u] = cur_p
        if not improved:
            break
    return parent, t_cur


def plan_ftr(net: OverlayNetwork, params: CodeParams,
             region: FeasibleRegion | None = None,
             core_sizes: Optional[List[int]] = None,
             local_search: bool = True,
             witness: str = "exact") -> RepairPlan:
    """Algorithm 2 over all core sizes i, plus the TR tree as a candidate.

    ``witness`` picks the final traffic-minimal witness engine: the exact
    level-cut oracle (default) or the scipy LP (``witness="lp"``)."""
    if witness not in ("exact", "lp"):   # eager: fail before the tree search
        raise ValueError(f"unknown witness engine {witness!r}")
    d = params.d
    if region is None:
        region = msr_region(params) if params.is_msr else heuristic_region(params)

    candidates: List[Dict[int, int]] = []
    sizes = core_sizes if core_sizes is not None else list(range(0, d + 1))
    for i in sizes:
        core = _grow_core(net, i, d)
        candidates.append(_initial_tree(net, core, d))
    candidates.append(dict(plan_tr(net, params).parent))  # dominance over TR

    # evaluate every candidate tree, then local-search the few best
    scored: List[Tuple[float, Dict[int, int]]] = []
    seen = set()
    incumbent = math.inf
    for cand in candidates:
        key = tuple(sorted(cand.items()))
        if key in seen:
            continue
        seen.add(key)
        if incumbent is math.inf:
            t, _ = eval_tree(cand, net, params, region)
        elif _feasible_at(incumbent, cand, net, params, region):
            t = _refine(cand, net, params, region, incumbent)
        else:  # exact: cannot beat the incumbent time
            t = math.inf
        incumbent = min(incumbent, t)
        scored.append((t, cand))
    scored.sort(key=lambda x: x[0])

    best_t, best_parent = scored[0]
    if local_search:
        for t, cand in scored[:3]:
            if t is math.inf:
                continue
            cand, t = _local_search(dict(cand), net, params, region, t)
            if t < best_t:
                best_parent, best_t = dict(cand), t

    assert best_parent is not None
    # final high-precision solve on the winning tree, then the
    # traffic-minimal witness at the optimal time
    t_star, betas = eval_tree(best_parent, net, params, region,
                              iters=FINAL_ITERS, minimize_traffic=True,
                              witness=witness)
    if betas is None:  # pragma: no cover - winning tree is feasible by search
        raise RuntimeError("FTR: winning tree lost feasibility at final solve")
    flows = tree_flows(best_parent, betas, params.alpha)
    time = 0.0
    for (u, v), f in flows.items():
        c = net.c(u, v)
        time = max(time, f / c if c > 0 else math.inf)
    return RepairPlan("ftr", params, best_parent, betas, flows, time,
                      lower_bound=t_star)
