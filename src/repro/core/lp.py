"""LP machinery for flexible repair traffic (paper problems (1) and (5)).

Key building blocks:

* ``minmax_time_star`` — problem (1) over a Theorem-1-form region with per-
  provider rate caps beta_i <= t*c_i: exact via bisection.  For a fixed t the
  candidate set {0 <= beta_i <= min(t*c_i, alpha)} has a coordinate-wise
  maximum point, and every sigma_j is coordinate-wise non-decreasing, so
  feasibility at time t holds iff the max point satisfies all constraints.

* ``min_traffic_at_time`` — secondary objective: minimize total generated
  traffic sum(beta) at the optimal time (the min-max LP has many optima; the
  executor prefers the cheapest).  Solved exactly and LP-free by the
  level-cut oracle (``repro.core.witness``); ``witness="lp"`` falls back to
  scipy's HiGHS via the exact LP-dual encoding of "sum of the m smallest
  >= x":

      exists lam (free), mu_i >= 0:  m*lam - sum_i mu_i >= x,
                                     lam - mu_i <= beta_i  for all i.

* ``tree_optimal_time`` — problem (5)/(6): optimal flexible time on a fixed
  regeneration tree.  For fixed t each tree edge (u,v) either satisfies
  t*c(u,v) >= alpha (re-encoding makes it unconstraining, Section V-B) or
  imposes  sum_{x in S(u)} beta_x <= t*c(u,v); the induced set is convex, so
  bisection on t with an LP feasibility oracle is exact per tree.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # scipy is available in this environment; keep a fallback anyway.
    from scipy.optimize import linprog as _linprog

    HAVE_SCIPY = True
except Exception:  # pragma: no cover
    HAVE_SCIPY = False

from .params import CodeParams, Edge
from .regions import FeasibleRegion, sigma
from . import witness as _witness

BISECT_ITERS = 60   # star bisection depth (shared with repro.core.batched)
_BISECT_ITERS = BISECT_ITERS
_TOL = 1e-9


# ---------------------------------------------------------------------------
# Star topology (FR)
# ---------------------------------------------------------------------------

def _star_feasible_at(t: float, caps: Sequence[float], region: FeasibleRegion,
                      alpha: float) -> bool:
    beta_hat = [min(t * c, alpha) for c in caps]
    return region.contains(beta_hat, tol=1e-12)


def minmax_time_star(caps: Sequence[float], region: FeasibleRegion,
                     alpha: float) -> float:
    """Exact optimum of problem (1) for a star topology."""
    d = len(caps)
    if any(c <= 0 for c in caps):
        # a zero-capacity direct link can still be fine if beta_i = 0 is
        # allowed; the max-point test handles it (beta_hat_i = 0).
        pass
    hi = 1.0
    while not _star_feasible_at(hi, caps, region, alpha):
        hi *= 2.0
        if hi > 1e18:
            return math.inf
    lo = 0.0
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        if _star_feasible_at(mid, caps, region, alpha):
            hi = mid
        else:
            lo = mid
    return hi


def min_traffic_at_time(t: float, caps: Sequence[float], region: FeasibleRegion,
                        alpha: float, witness: str = "exact") -> List[float]:
    """Min sum(beta) subject to beta in region, 0 <= beta_i <= min(t*c_i, alpha).

    ``witness="exact"`` (default) is the LP-free level-cut oracle
    (:mod:`repro.core.witness`); ``witness="lp"`` keeps the scipy/HiGHS
    solve as the correctness oracle (falls through to the exact oracle when
    scipy is absent or the LP fails at the feasibility boundary).
    """
    if witness not in ("exact", "lp"):
        raise ValueError(f"unknown witness engine {witness!r}")
    ub = [min(t * c, alpha) for c in caps]
    if witness == "lp" and HAVE_SCIPY:
        sol = _min_traffic_lp(ub, region)
        if sol is not None:
            return sol
    return _witness.level_cut(ub, region)


def _min_traffic_lp(ub: Sequence[float], region: FeasibleRegion) -> Optional[List[float]]:
    d = len(ub)
    k = region.k
    # variables z = [beta (d), lam (k), mu (k*d)]
    nv = d + k + k * d
    c = np.zeros(nv)
    c[:d] = 1.0
    A, b = [], []
    for j in range(1, k + 1):
        m = region.d - region.k + j
        # -m*lam_j + sum_i mu_ji <= -x_j
        row = np.zeros(nv)
        row[d + (j - 1)] = -m
        row[d + k + (j - 1) * d: d + k + j * d] = 1.0
        A.append(row)
        b.append(-region.x[j - 1])
        # lam_j - mu_ji - beta_i <= 0
        for i in range(d):
            row = np.zeros(nv)
            row[d + (j - 1)] = 1.0
            row[d + k + (j - 1) * d + i] = -1.0
            row[i] = -1.0
            A.append(row)
            b.append(0.0)
    bounds = [(0.0, u) for u in ub] + [(None, None)] * k + [(0.0, None)] * (k * d)
    res = _linprog(c, A_ub=np.array(A), b_ub=np.array(b), bounds=bounds,
                   method="highs")
    if not res.success:
        return None
    beta = list(res.x[:d])
    # numerical safety: if a sigma constraint is violated by rounding, nudge up
    if not region.contains(beta, tol=1e-7):
        return None
    return beta


# ---------------------------------------------------------------------------
# Water-filling (leximin) oracle for laminar caps
# ---------------------------------------------------------------------------

def waterfill_max(ub: Sequence[float], laminar: Sequence[Tuple[Sequence[int], float]],
                  ) -> List[float]:
    """Leximin-maximal vector under per-coordinate caps ``ub`` and laminar
    set caps ``laminar`` = [(coordinate index list, bound), ...].

    Laminar caps form a polymatroid; the water-filled (lexicographically
    optimal) maximal vector simultaneously maximizes every sum-of-m-smallest
    sigma_m over the polytope (Fujishige's lexicographically optimal bases).
    Used as an exact, LP-free feasibility oracle for the fixed-tree problem;
    cross-validated against the scipy LP in tests/test_core_properties.py.
    """
    d = len(ub)
    ub_arr = np.asarray(ub, dtype=np.float64)
    v = np.zeros(d)
    active = np.ones(d, dtype=bool)
    if laminar:
        inc = np.zeros((len(laminar), d), dtype=np.float64)
        bnd = np.empty(len(laminar))
        for si, (S, B) in enumerate(laminar):
            for i in S:
                inc[si, i] = 1.0
            bnd[si] = B
    else:
        inc = np.zeros((0, d))
        bnd = np.zeros(0)
    while active.any():
        lam = np.inf
        freeze_set = -1
        # candidate level from per-coordinate caps
        coord_min = ub_arr[active].min()
        lam = coord_min
        if len(bnd):
            na = inc @ active
            frozen_sum = inc @ (v * ~active)
            with np.errstate(divide="ignore", invalid="ignore"):
                cand = np.where(na > 0, (bnd - frozen_sum) / np.maximum(na, 1), np.inf)
            si = int(np.argmin(cand))
            if cand[si] < lam - 1e-15:
                lam = cand[si]
                freeze_set = si
        lam = max(lam, 0.0)
        if freeze_set >= 0:
            members = (inc[freeze_set] > 0) & active
            v[members] = lam
            active &= ~members
        else:
            members = active & (ub_arr <= lam + 1e-15)
            v[members] = ub_arr[members]
            active &= ~members
    return v.tolist()


# ---------------------------------------------------------------------------
# Fixed-tree flexible traffic (FTR inner problem)
# ---------------------------------------------------------------------------

def _subtree_sets(parent: Dict[int, int], d: int) -> Dict[int, List[int]]:
    children: Dict[int, List[int]] = {}
    for u, p in parent.items():
        children.setdefault(p, []).append(u)
    subs: Dict[int, List[int]] = {}

    def visit(u: int) -> List[int]:
        acc = [u]
        for ch in children.get(u, []):
            acc.extend(visit(ch))
        subs[u] = acc
        return acc

    for r in children.get(0, []):
        visit(r)
    return subs


def tree_feasible_at_time(t: float, parent: Dict[int, int],
                          cap_of_edge: Dict[Edge, float],
                          region: FeasibleRegion, alpha: float,
                          minimize_traffic: bool = False,
                          witness: str = "exact") -> Optional[List[float]]:
    """Feasibility oracle: is there beta >= 0 in ``region`` such that every
    tree edge carries min(subtree-sum, alpha) <= t * c(edge)?  Returns a
    witness beta (len d) or None.

    For fixed t the edge constraint resolves deterministically:
      * t*c >= alpha  -> edge never binds (interior re-encoding caps the flow)
      * t*c <  alpha  -> sum_{x in S(u)} beta_x <= t*c

    Default oracle is the exact water-fill (leximin maximizes every sigma_j
    over the laminar polytope); ``minimize_traffic=True`` additionally
    minimizes total traffic among feasible witnesses (used for the final
    plan) — by the exact level cut of the water-fill point, or via the
    scipy LP when ``witness="lp"``.
    """
    if witness not in ("exact", "lp"):
        raise ValueError(f"unknown witness engine {witness!r}")
    d = region.d
    subs = _subtree_sets(parent, d)
    caps: List[Tuple[List[int], float]] = []  # (subtree provider list, bound)
    for u, p in parent.items():
        c = cap_of_edge[(u, p)]
        bound = t * c
        if bound >= alpha - 1e-12:
            continue
        caps.append((subs[u], bound))
    # per-provider implicit cap beta_i <= alpha
    ub = [alpha] * d

    if minimize_traffic and witness == "lp" and HAVE_SCIPY:
        # exact LP oracle + solver-chosen traffic-minimal vertex
        return _tree_lp(caps, ub, region)
    wf = waterfill_max(ub, [([x - 1 for x in S], B) for S, B in caps])
    if not region.contains(wf, tol=1e-9):
        return None
    if minimize_traffic:
        # a uniform level cap commutes with the water-fill (freeze levels
        # only rise), so the traffic-minimal point is a level cut of wf
        return _witness.tree_min_traffic(wf, region)
    return wf


def _tree_lp(caps, ub, region: FeasibleRegion) -> Optional[List[float]]:
    d, k = region.d, region.k
    nv = d + k + k * d
    c = np.zeros(nv)
    c[:d] = 1.0  # among feasible points prefer low total traffic
    A, b = [], []
    for nodes, bound in caps:
        row = np.zeros(nv)
        for x in nodes:
            row[x - 1] = 1.0
        A.append(row)
        b.append(bound)
    for j in range(1, k + 1):
        m = region.d - region.k + j
        row = np.zeros(nv)
        row[d + (j - 1)] = -m
        row[d + k + (j - 1) * d: d + k + j * d] = 1.0
        A.append(row)
        b.append(-region.x[j - 1])
        for i in range(d):
            row = np.zeros(nv)
            row[d + (j - 1)] = 1.0
            row[d + k + (j - 1) * d + i] = -1.0
            row[i] = -1.0
            A.append(row)
            b.append(0.0)
    bounds = [(0.0, u) for u in ub] + [(None, None)] * k + [(0.0, None)] * (k * d)
    res = _linprog(c, A_ub=np.array(A), b_ub=np.array(b), bounds=bounds,
                   method="highs")
    if not res.success:
        return None
    beta = list(res.x[:d])
    if not region.contains(beta, tol=1e-7):
        return None
    return beta


def tree_optimal_time(parent: Dict[int, int], cap_of_edge: Dict[Edge, float],
                      region: FeasibleRegion, alpha: float,
                      iters: int = 40, minimize_traffic: bool = False,
                      witness: str = "exact",
                      ) -> Tuple[float, Optional[List[float]]]:
    """Problem (5): min t such that a feasible beta exists on this tree.

    Bisection with the water-fill oracle; ``minimize_traffic=True`` extracts
    the traffic-minimal witness at the final time (exact level cut by
    default, scipy's vertex with ``witness="lp"``).
    """
    pos = [c for c in cap_of_edge.values()]
    if any(c <= 0 for c in pos):
        return math.inf, None
    hi = max(alpha / c for c in pos) * (1 + 1e-9) + 1e-12
    if tree_feasible_at_time(hi, parent, cap_of_edge, region, alpha) is None:
        while hi < 1e18:
            hi *= 2
            if tree_feasible_at_time(hi, parent, cap_of_edge, region, alpha) is not None:
                break
        else:
            return math.inf, None
    lo = 0.0
    beta = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        w = tree_feasible_at_time(mid, parent, cap_of_edge, region, alpha)
        if w is not None:
            hi, beta = mid, w
        else:
            lo = mid
    if minimize_traffic:
        w = tree_feasible_at_time(hi, parent, cap_of_edge, region, alpha,
                                  minimize_traffic=True, witness=witness)
        if w is not None:
            beta = w
    if beta is None:
        beta = tree_feasible_at_time(hi, parent, cap_of_edge, region, alpha)
    return hi, beta
