"""Tree-structured regeneration with constant repair traffic (TR, Section IV).

Theorem 3: on a regeneration tree T rooted at the newcomer, the minimum
MDS-preserving flow on edge (u, v) is  min(m_u * beta, alpha)  where m_u is
the subtree size of u and beta the conventional uniform traffic.

Building the optimal tree (ORT) is NP-hard (Theorem 4, reduction from
VERTEX-COVER); Algorithm 1 is the paper's Prim-like O(|V|^3) heuristic:
grow the tree from the newcomer, each step attaching the (provider,
position) pair that minimizes the regeneration time of the partial tree.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .params import CodeParams, OverlayNetwork, RepairPlan, tree_flows


def tree_time_uniform(parent: Dict[int, int], net: OverlayNetwork,
                      params: CodeParams) -> float:
    """Regeneration time of a tree under uniform per-provider traffic beta
    with Theorem-3 flows."""
    betas = [params.beta] * params.d
    flows = tree_flows(parent, betas, params.alpha)
    t = 0.0
    for (u, v), f in flows.items():
        c = net.c(u, v)
        if c <= 0:
            return math.inf
        t = max(t, f / c)
    return t


def plan_tr(net: OverlayNetwork, params: CodeParams) -> RepairPlan:
    """Algorithm 1: greedy tree construction."""
    d = params.d
    parent: Dict[int, int] = {}
    in_tree = {0}
    remaining = set(range(1, d + 1))

    while remaining:
        # Tie-break: among equal partial times prefer the candidate whose new
        # edge (v -> u) has the larger capacity c(v, u) — capacities are
        # directed, so the child->parent direction matters.  The key is stored
        # alongside the winner rather than recomputed from the stored (v, u)
        # at every comparison, so the comparison provably uses the same
        # quantity that was minimized.
        best: Optional[Tuple[int, int]] = None
        best_key: Optional[Tuple[float, float]] = None
        for v in sorted(remaining):
            for u in sorted(in_tree):
                cand = dict(parent)
                cand[v] = u
                t = _partial_time(cand, net, params)
                key = (t, -net.c(v, u))
                if best_key is None or key < best_key:
                    best, best_key = (v, u), key
        assert best is not None
        v, u = best
        parent[v] = u
        in_tree.add(v)
        remaining.discard(v)

    betas = [params.beta] * d
    flows = tree_flows(parent, betas, params.alpha)
    time = tree_time_uniform(parent, net, params)
    return RepairPlan("tr", params, parent, betas, flows, time)


def _partial_time(parent: Dict[int, int], net: OverlayNetwork,
                  params: CodeParams) -> float:
    """Time of a partial tree: Theorem-3 flows over the attached providers
    only (each attached provider contributes beta)."""
    betas = [0.0] * params.d
    for u in parent:
        betas[u - 1] = params.beta
    flows = tree_flows(parent, betas, params.alpha)
    t = 0.0
    for (u, v), f in flows.items():
        c = net.c(u, v)
        if c <= 0:
            return math.inf
        t = max(t, f / c)
    return t
