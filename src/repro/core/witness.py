"""Exact combinatorial min-traffic witness oracle (star and tree cases).

The planners need a *traffic-minimal* witness beta at the optimal repair
time: problem (1)'s secondary objective for FR, and the final flexible
betas on FTR's winning tree.  Both used to be one scipy/HiGHS ``linprog``
call per Monte-Carlo trial — the last scalar island in the batched engine
(~1.6 ms each, ~40% of the fig6 d=6 row).  This module replaces the LP with
an exact O(d log d) closed form that vectorizes across the whole batch.

Structure.  In both cases the witness problem is

    min sum(beta)   s.t.   sigma_j(beta) >= x_j  (j = 1..k),   0 <= beta <= ub

where sigma_j is the sum of the (d-k+j) smallest components (Theorem 1) and
``ub`` is a coordinate-wise *maximal* feasible point:

* star (``lp.min_traffic_at_time``): ub_i = min(t * c_i, alpha) — the
  Theorem-1 max point the bisection already certified;
* tree (``lp._tree_lp``): ub = the water-fill witness of the laminar
  subtree caps at time t (``lp.waterfill_max`` / ``batched.waterfill_batch``).
  A uniform level cap commutes with the water-fill — freeze levels only rise
  during filling, so capping every coordinate at ``lam`` before filling
  equals filling first and clipping at ``lam`` (min(wf, lam)).  The laminar
  caps therefore stay satisfied under any level cut of ``wf``, which reduces
  the tree case to the star case with ub = wf.

Level-cut solution.  Candidates beta = min(ub, lam) sweep a monotone family:
every sigma_j is non-decreasing in lam, so the minimal feasible level is
determined per constraint.  With s = sort(ub) ascending, prefix sums
S_p = s_1 + ... + s_p and m_j = d - k + j,

    sum_{i <= m_j} min(s_i, lam)  =  min_p ( S_p + (m_j - p) * lam ),

hence sigma_j(min(ub, lam)) >= x_j  iff  lam >= (x_j - S_p) / (m_j - p) for
every p < m_j, and the exact optimal level is

    lam* = max(0, max_{j, p < m_j} (x_j - S_p) / (m_j - p)).

``min(ub, lam*)`` attains the LP optimum of sum(beta) (cross-validated
against HiGHS in tests/test_witness.py).

Tie-break contract.  The LP optimum can be a face, not a point; a witness
is only reproducible if its position on that face is pinned.  This oracle
always returns the *level-cut point* ``min(ub, lam*)`` — the most balanced
optimal vector (it minimizes the maximum coordinate over the optimal face),
deterministic, independent of batch composition, and exempt from solver
internals.  On star instances this coincides with HiGHS's vertex choice
(audited across the repo's instance family; asserted per-edge to 1e-9 in
tests/test_witness.py).  On degenerate tree faces HiGHS's dual simplex may
return a different vertex of the same face — equal generated traffic
sum(beta) and equal repair time, but individual betas (and hence relayed
bytes on non-binding edges) can differ; the level-cut point is the
canonical witness, and ``witness="lp"`` on the planners reproduces the old
solver-chosen vertex exactly.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .regions import FeasibleRegion

__all__ = [
    "level_cut_batch",
    "level_cut",
    "min_traffic_batch",
    "tree_traffic_batch",
    "min_traffic",
    "tree_min_traffic",
]


_FEAS_TOL = 1e-7    # matches the LP acceptance tolerance in repro.core.lp


def min_level_batch(ub: np.ndarray, region: FeasibleRegion,
                    lanes: Optional[np.ndarray] = None) -> np.ndarray:
    """Exact minimal level ``lam*`` per lane such that ``min(ub, lam*)``
    satisfies every Theorem-1 constraint of ``region``.

    ``ub`` is (B, d).  Returns (B,).  Every live lane's ``ub`` must itself
    satisfy the region (the callers' bisections certify exactly that);
    an infeasible live lane raises ValueError — the same contract the old
    scipy-absent greedy enforced — instead of returning a silently invalid
    witness.  Lanes outside ``lanes`` are not checked (their result is
    discarded by the callers).
    """
    ub = np.asarray(ub, dtype=np.float64)
    B, d = ub.shape
    k = region.k
    s = np.sort(ub, axis=1)
    S = np.concatenate([np.zeros((B, 1)), np.cumsum(s, axis=1)], axis=1)
    p = np.arange(d)                                    # prefix sizes 0..d-1
    m = d - k + np.arange(1, k + 1)                     # m_j, shape (k,)
    x = np.asarray(region.x, dtype=np.float64)
    # sigma_j(ub) = S[m_j] is the largest reachable value of constraint j
    slack = x[None, :] - S[:, m]                        # (B, k)
    bad = (slack > _FEAS_TOL * np.maximum(1.0, np.abs(x))[None, :]).any(axis=1)
    if lanes is not None:
        bad &= lanes
    if bad.any():
        raise ValueError(
            f"infeasible even at the coordinate-wise max point in "
            f"{int(bad.sum())} of {B} lanes (first: lane "
            f"{int(np.argmax(bad))})")
    denom = m[None, :, None] - p[None, None, :]         # (1, k, d)
    with np.errstate(divide="ignore", invalid="ignore"):
        cand = (x[None, :, None] - S[:, None, :d]) / denom
    cand = np.where(denom > 0, cand, -np.inf)           # only p < m_j bind
    return np.maximum(cand.max(axis=(1, 2)), 0.0)


def level_cut_batch(ub: np.ndarray, region: FeasibleRegion,
                    lanes: Optional[np.ndarray] = None) -> np.ndarray:
    """Traffic-minimal witnesses ``min(ub, lam*)`` for a (B, d) batch of
    coordinate-wise maximal points ``ub`` (see module docstring)."""
    ub = np.asarray(ub, dtype=np.float64)
    lam = min_level_batch(ub, region, lanes=lanes)
    return np.minimum(ub, lam[:, None])


def level_cut(ub: Sequence[float], region: FeasibleRegion) -> List[float]:
    """Scalar wrapper of :func:`level_cut_batch` (one lane) — the scalar
    planners share the batched arithmetic bit for bit."""
    return level_cut_batch(np.asarray(ub, dtype=np.float64)[None, :],
                           region)[0].tolist()


# ---------------------------------------------------------------------------
# Star case (FR): problem (1)'s secondary objective
# ---------------------------------------------------------------------------

def min_traffic_batch(t: np.ndarray, direct: np.ndarray,
                      region: FeasibleRegion, alpha: float,
                      lanes: Optional[np.ndarray] = None) -> np.ndarray:
    """Batched ``lp.min_traffic_at_time``: traffic-minimal star betas at the
    per-lane times ``t`` over direct capacities ``direct`` (B, d).

    Lanes outside ``lanes`` (or with non-finite ``t``) return zeros, matching
    ``plan_fr_batch``'s convention for infeasible lanes.
    """
    t = np.asarray(t, dtype=np.float64)
    direct = np.asarray(direct, dtype=np.float64)
    B, d = direct.shape
    live = np.isfinite(t) if lanes is None else (lanes & np.isfinite(t))
    ub = np.minimum(np.where(live, t, 0.0)[:, None] * direct, alpha)
    betas = level_cut_batch(ub, region, lanes=live)
    return np.where(live[:, None], betas, 0.0)


def min_traffic(t: float, caps: Sequence[float], region: FeasibleRegion,
                alpha: float) -> List[float]:
    """Scalar star witness: min sum(beta) over ``region`` with
    beta_i <= min(t * c_i, alpha) (exact, LP-free)."""
    ub = [min(t * c, alpha) for c in caps]
    return level_cut(ub, region)


# ---------------------------------------------------------------------------
# Tree case (FTR): traffic-minimal betas on a fixed regeneration tree
# ---------------------------------------------------------------------------

def tree_traffic_batch(t: np.ndarray, parents: np.ndarray, caps: np.ndarray,
                       region: FeasibleRegion, alpha: float,
                       lanes: Optional[np.ndarray] = None) -> np.ndarray:
    """Batched ``lp._tree_lp``: traffic-minimal betas at per-lane times ``t``
    on the trees ``parents`` (B, d+1) over capacity tensors ``caps``.

    One water-fill (the same oracle the bisection already runs) plus one
    level cut; no per-trial Python.  Lanes outside ``lanes`` return zeros.
    ``plan_ftr_batch`` inlines the equivalent two calls to reuse the
    water-fill witness it already has.
    """
    from . import batched  # local import: batched imports this module

    t = np.asarray(t, dtype=np.float64)
    live = np.isfinite(t) if lanes is None else (lanes & np.isfinite(t))
    mask, edge_caps = batched._tree_arrays(caps, parents)
    _, wf = batched.tree_feasible_batch(np.where(live, t, 1.0), mask,
                                        edge_caps, region, alpha)
    betas = level_cut_batch(wf, region, lanes=live)
    return np.where(live[:, None], betas, 0.0)


def tree_min_traffic(wf: Sequence[float], region: FeasibleRegion,
                     ) -> List[float]:
    """Scalar tree witness from an already-computed water-fill point ``wf``
    (the feasibility witness at the target time): its level cut is the
    traffic-minimal vector on the tree (see module docstring)."""
    return level_cut(wf, region)
