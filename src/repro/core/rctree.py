"""RCTREE baseline (Li et al. [7]) — the prior tree scheme that LOSES the
MDS property (paper Appendix A).

RCTREE builds a maximum-bottleneck regeneration tree with the constraint
that the newcomer keeps degree >= d-k+1, and transmits a *fixed* beta on
every edge (interior nodes combine their own alpha blocks with received
blocks into just beta coded blocks).  Because interior edges carry beta
instead of min(m_u * beta, alpha), downstream information is destroyed and
some k-subsets can no longer rebuild the file (Fig. 9 / Fig. 10).
"""
from __future__ import annotations

import math
from typing import Dict, List

from .params import CodeParams, OverlayNetwork, RepairPlan


def plan_rctree(net: OverlayNetwork, params: CodeParams) -> RepairPlan:
    d = params.d
    b = params.beta
    # Prim-style maximum-bottleneck spanning tree from the newcomer.
    parent: Dict[int, int] = {}
    in_tree = [0]
    remaining = set(range(1, d + 1))
    while remaining:
        best_u, best_v, best_c = None, None, -1.0
        for u in remaining:
            for v in in_tree:
                if net.c(u, v) > best_c:
                    best_u, best_v, best_c = u, v, net.c(u, v)
        parent[best_u] = best_v
        in_tree.append(best_u)
        remaining.discard(best_u)

    # enforce newcomer degree >= d-k+1 ([7], Algorithm 1): re-attach the
    # cheapest interior children directly to the root until satisfied.
    def root_degree() -> int:
        return sum(1 for p in parent.values() if p == 0)

    while root_degree() < params.d - params.k + 1:
        cands = [u for u in parent if parent[u] != 0]
        u = max(cands, key=lambda u: net.c(u, 0))
        parent[u] = 0

    flows = {(u, p): b for u, p in parent.items()}  # fixed beta per edge!
    t = 0.0
    for (u, p), f in flows.items():
        c = net.c(u, p)
        t = max(t, f / c if c > 0 else math.inf)
    betas = [b] * d
    plan = RepairPlan("rctree", params, parent, betas, flows, t)
    return plan
