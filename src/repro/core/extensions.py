"""Beyond-paper extensions (DESIGN.md §6).

1. Transfer-model robustness.  The paper's regeneration time
   max_e f(e)/c(e) assumes *streaming*: every edge transmits concurrently
   and interior nodes re-encode in flight (Section II: "coding operations
   are streamlined with the data transmission").  Real relays may
   store-and-forward (receive a full shard, then re-encode and send);
   ``store_and_forward_time`` evaluates a plan under that pessimistic
   model: t(u) = max over children t(child) + f(u, parent)/c(u, parent).
   Tree schemes lose part of their advantage under S&F while STAR/FR are
   unaffected — a robustness axis the paper does not study.
   ``streaming_time_with_latency`` adds per-hop pipeline-fill latency
   (depth * block_time) to the paper's model.

2. Concurrent multi-failure recovery.  ``plan_multi_failures`` plans r
   simultaneous regenerations with shared providers/links: repairs are
   planned sequentially (most-constrained newcomer first) and each planned
   repair deflates the residual capacity of the links it occupies, so later
   plans route around contended links.  Newcomers never serve as providers
   for one another (their data is not yet regenerated), so each individual
   plan keeps the MDS property by Theorems 3/5.
"""
from __future__ import annotations

import copy
import math
from typing import Callable, Dict, List, Sequence, Tuple

from .params import CodeParams, Edge, OverlayNetwork, RepairPlan
from .star import plan_fr
from .ftr import plan_ftr


# ---------------------------------------------------------------------------
# transfer models
# ---------------------------------------------------------------------------

def store_and_forward_time(plan: RepairPlan, net: OverlayNetwork) -> float:
    """Pessimistic relay model: an interior node forwards only after fully
    receiving its children."""
    children: Dict[int, List[int]] = {}
    for u, p in plan.parent.items():
        children.setdefault(p, []).append(u)

    def finish(u: int) -> float:
        child_t = max((finish(ch) for ch in children.get(u, [])), default=0.0)
        f = plan.flows[(u, plan.parent[u])]
        c = net.c(u, plan.parent[u])
        if c <= 0:
            return math.inf
        return child_t + f / c

    return max((finish(r) for r in children.get(0, [])), default=0.0)


def streaming_time_with_latency(plan: RepairPlan, net: OverlayNetwork,
                                block_time: float = 0.0) -> float:
    """Paper model + pipeline-fill latency: depth(u) * block_time added to
    each root-to-leaf chain (negligible for large files, visible for small
    checkpoint shards)."""
    children: Dict[int, List[int]] = {}
    for u, p in plan.parent.items():
        children.setdefault(p, []).append(u)

    def depth(u: int) -> int:
        return 1 + max((depth(ch) for ch in children.get(u, [])), default=0)

    base = 0.0
    for (u, v), f in plan.flows.items():
        c = net.c(u, v)
        base = max(base, f / c if c > 0 else math.inf)
    max_depth = max((depth(r) for r in children.get(0, [])), default=0)
    return base + max_depth * block_time


# ---------------------------------------------------------------------------
# concurrent multi-failure planning
# ---------------------------------------------------------------------------

def plan_multi_failures(params: CodeParams,
                        overlays: Sequence[OverlayNetwork],
                        planner: Callable = plan_ftr,
                        contention: float = 1.0,
                        ) -> List[Tuple[RepairPlan, float]]:
    """Plan len(overlays) simultaneous repairs.

    ``overlays[i]`` is the overlay of the i-th newcomer (node 0) against its
    own d providers; provider index j in different overlays may denote the
    same physical host — the caller encodes that by passing shared
    ``link_ids``-free overlays and a ``contention`` factor in [0, 1]: after
    each planned repair, every overlay link whose *source provider index*
    carried flow is deflated proportionally to its busy fraction.

    Returns [(plan, predicted_time)] in planning order (most-constrained
    first: smallest best direct capacity)."""
    order = sorted(range(len(overlays)),
                   key=lambda i: max(overlays[i].direct_caps()))
    nets = [copy.deepcopy(o) for o in overlays]
    out: List[Tuple[RepairPlan, float]] = [None] * len(overlays)  # type: ignore
    for idx in order:
        net = nets[idx]
        plan = planner(net, params)
        t = plan.time
        out[idx] = (plan, t)
        if t <= 0 or contention <= 0:
            continue
        # deflate residual capacity on links used by this plan for the
        # remaining (concurrent) repairs: a provider busy for fraction
        # busy = (f/c)/t of the window has (1 - contention*busy) left
        for (u, v), f in plan.flows.items():
            c = net.c(u, v)
            if c <= 0:
                continue
            busy = min((f / c) / t, 1.0)
            scale = max(1.0 - contention * busy, 0.05)
            for later in order[order.index(idx) + 1:]:
                ln = nets[later]
                for a in range(ln.num_nodes):
                    # provider u's outgoing links contend in every overlay
                    if u < ln.num_nodes:
                        ln.cap[u][a] *= scale
        # replace: after deflation later plans see reduced capacity
    return out
