"""Brute-force Optimal Regeneration Tree (exact, exponential).

The ORT problem is NP-hard (Theorem 4); for small d we enumerate every
rooted spanning tree of the complete overlay (Cayley: (d+1)^(d-1) trees) to
obtain the exact optimum, used to measure the optimality gap of the TR and
FTR heuristics in tests and benchmarks.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Iterator, Optional, Tuple

from .params import CodeParams, OverlayNetwork, RepairPlan, tree_flows
from .regions import FeasibleRegion, heuristic_region, msr_region
from .tree import tree_time_uniform
from .ftr import eval_tree


def iter_rooted_trees(d: int) -> Iterator[Dict[int, int]]:
    """All parent maps over providers 1..d rooted at 0 (no cycles)."""
    nodes = list(range(1, d + 1))
    for choice in itertools.product(range(0, d + 1), repeat=d):
        parent = {}
        ok = True
        for u, p in zip(nodes, choice):
            if p == u:
                ok = False
                break
            parent[u] = p
        if not ok:
            continue
        # reject cycles (every node must reach 0)
        good = True
        for u in nodes:
            seen, x = set(), u
            while x != 0:
                if x in seen:
                    good = False
                    break
                seen.add(x)
                x = parent[x]
            if not good:
                break
        if good:
            yield parent


def plan_ort_uniform(net: OverlayNetwork, params: CodeParams) -> RepairPlan:
    """Exact TR optimum: best tree under uniform traffic (Theorem-3 flows)."""
    best_parent, best_t = None, math.inf
    for parent in iter_rooted_trees(params.d):
        t = tree_time_uniform(parent, net, params)
        if t < best_t:
            best_parent, best_t = dict(parent), t
    assert best_parent is not None
    betas = [params.beta] * params.d
    flows = tree_flows(best_parent, betas, params.alpha)
    return RepairPlan("ort", params, best_parent, betas, flows, best_t)


def plan_ort_flexible(net: OverlayNetwork, params: CodeParams,
                      region: Optional[FeasibleRegion] = None) -> RepairPlan:
    """Exact FTR optimum: best tree under flexible traffic (LP per tree)."""
    if region is None:
        region = msr_region(params) if params.is_msr else heuristic_region(params)
    best_parent, best_t = None, math.inf
    for parent in iter_rooted_trees(params.d):
        t, _ = eval_tree(parent, net, params, region, iters=30)
        if t < best_t:
            best_parent, best_t = dict(parent), t
    assert best_parent is not None
    t_star, betas = eval_tree(best_parent, net, params, region, iters=50)
    assert betas is not None
    flows = tree_flows(best_parent, betas, params.alpha)
    time = 0.0
    for (u, v), f in flows.items():
        c = net.c(u, v)
        time = max(time, f / c if c > 0 else math.inf)
    return RepairPlan("ort_flex", params, best_parent, betas, flows, time,
                      lower_bound=t_star)
