"""Generalized information flow graphs and MDS verification (Sections II, IV).

Builds the information flow graph of a *repair history* — the initial n
storage nodes plus a sequence of (tree, flows)-regenerations — and checks
the MDS property via max-flow: the file is recoverable from a set K of k
storage nodes iff min-cut(s, DC_K) >= M (Lemma 1).  This is the tool the
paper uses both to prove its schemes safe (Theorems 3, 5) and to exhibit
RCTREE's failure (Appendix A).

Graph construction (Section IV-A):
  * source s -> u_in (inf) for each initial node u;
  * u_in -> u_out with capacity alpha for every storage node;
  * repair of newcomer w over tree T with flows f:
      - provider u sending f(u, x) to interior provider x:  u_out -> x_out
        (capacity f(u, x)) — the relay re-encodes in flight, it does not
        pass through x's storage;
      - provider u sending f(u, w) to the newcomer:  u_out -> w_in;
  * data collector DC -> k chosen out-nodes with infinite capacity.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .params import CodeParams, Edge

INF = float("inf")


@dataclasses.dataclass
class RepairEvent:
    """One regeneration: ``newcomer`` (storage-node id) regenerated from the
    providers appearing in ``tree`` with per-edge block counts ``flows``.

    ``tree``/``flows`` are keyed on *storage-node ids* (not overlay indices);
    the newcomer is the tree root.
    """

    newcomer: int
    parent: Dict[int, int]          # provider -> parent (parent may be newcomer)
    flows: Dict[Edge, float]        # (u, parent(u)) -> blocks


class _MaxFlow:
    """Dinic with float capacities (graphs here have < 10^3 nodes)."""

    def __init__(self):
        self.graph: List[List[int]] = []
        self.to: List[int] = []
        self.cap: List[float] = []

    def add_node(self) -> int:
        self.graph.append([])
        return len(self.graph) - 1

    def add_edge(self, u: int, v: int, c: float) -> None:
        self.graph[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(c)
        self.graph[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(0.0)

    def max_flow(self, s: int, t: int, limit: float = INF) -> float:
        flow = 0.0
        eps = 1e-9
        while flow < limit - eps:
            # BFS level graph
            level = [-1] * len(self.graph)
            level[s] = 0
            q = [s]
            for u in q:
                for e in self.graph[u]:
                    if self.cap[e] > eps and level[self.to[e]] < 0:
                        level[self.to[e]] = level[u] + 1
                        q.append(self.to[e])
            if level[t] < 0:
                break
            it = [0] * len(self.graph)

            def dfs(u: int, f: float) -> float:
                if u == t:
                    return f
                while it[u] < len(self.graph[u]):
                    e = self.graph[u][it[u]]
                    v = self.to[e]
                    if self.cap[e] > eps and level[v] == level[u] + 1:
                        d = dfs(v, min(f, self.cap[e]))
                        if d > eps:
                            self.cap[e] -= d
                            self.cap[e ^ 1] += d
                            return d
                    it[u] += 1
                return 0.0

            while True:
                f = dfs(s, limit - flow)
                if f <= eps:
                    break
                flow += f
        return flow


class InfoFlowGraph:
    """Information flow graph over a repair history."""

    def __init__(self, params: CodeParams, initial_nodes: Sequence[int]):
        self.params = params
        self.events: List[RepairEvent] = []
        self.initial = list(initial_nodes)
        self.live: List[int] = list(initial_nodes)   # current storage nodes

    def fail_and_repair(self, failed: int, event: RepairEvent) -> None:
        if failed not in self.live:
            raise ValueError(f"node {failed} is not live")
        providers = set(event.parent.keys())
        if len(providers) != self.params.d:
            raise ValueError(f"need exactly d={self.params.d} providers, got {len(providers)}")
        if not providers <= set(self.live) - {failed}:
            raise ValueError("providers must be live survivors")
        self.live.remove(failed)
        self.live.append(event.newcomer)
        self.events.append(event)

    # -- flow-graph assembly -------------------------------------------------
    #
    # Deviation from the paper's construction (documented in DESIGN.md): the
    # paper adds relay links u_out -> w_out directly.  That lets information
    # relayed through w (but never *stored* by w, which keeps only alpha
    # blocks) be read by later consumers of w_out.  We instead create one
    # relay node per (event, provider): w's in-flight transmission may use
    # all of w's stored data (w_out -> w_ev, inf) plus what its tree children
    # delivered this round (child_ev -> w_ev, f(child, w)), and is capped by
    # the tree edge it sends on.  This is never larger than the paper's
    # min-cut, so schemes verified safe here are safe in the paper's model.

    def _build(self) -> Tuple[_MaxFlow, int, Dict[Tuple[int, int], int]]:
        """Returns (flow net, source id, (node, generation) -> out-node id).

        A storage id can be reused across time (replacement hosts); each
        (id, generation) pair is a distinct graph node.  ``gen[node]`` below
        tracks the latest generation per id as events are replayed.
        """
        net = _MaxFlow()
        s = net.add_node()
        alpha = self.params.alpha
        node_in: Dict[Tuple[int, int], int] = {}
        node_out: Dict[Tuple[int, int], int] = {}
        gen: Dict[int, int] = {}

        def new_storage(nid: int, from_source: bool) -> None:
            g = gen.get(nid, -1) + 1
            gen[nid] = g
            i = net.add_node()
            o = net.add_node()
            node_in[(nid, g)] = i
            node_out[(nid, g)] = o
            net.add_edge(i, o, alpha)
            if from_source:
                net.add_edge(s, i, INF)

        for nid in self.initial:
            new_storage(nid, from_source=True)

        for ev in self.events:
            # per-event relay nodes for every provider in the tree
            relay: Dict[int, int] = {}
            for u in ev.parent:
                relay[u] = net.add_node()
                gu = gen[u]
                net.add_edge(node_out[(u, gu)], relay[u], INF)
            new_storage(ev.newcomer, from_source=False)
            g_new = gen[ev.newcomer]
            for u, p in ev.parent.items():
                f = ev.flows[(u, p)]
                if p == ev.newcomer:
                    net.add_edge(relay[u], node_in[(ev.newcomer, g_new)], f)
                else:
                    net.add_edge(relay[u], relay[p], f)
        cur_out = {nid: node_out[(nid, gen[nid])] for nid in self.live}
        return net, s, cur_out

    # -- MDS checks ----------------------------------------------------------

    def collector_flow(self, nodes: Sequence[int]) -> float:
        """Max-flow from source to a data collector on ``nodes``."""
        net, s, cur_out = self._build()
        dc = net.add_node()
        for nid in nodes:
            net.add_edge(cur_out[nid], dc, INF)
        return net.max_flow(s, dc, limit=self.params.M * (1 + 1e-9) + 1.0)

    def mds_holds(self, tol: float = 1e-6) -> bool:
        """True iff every k-subset of live nodes can rebuild the file."""
        return self.worst_collector()[1] >= self.params.M * (1 - tol)

    def worst_collector(self) -> Tuple[Tuple[int, ...], float]:
        worst, worst_flow = (), INF
        for combo in itertools.combinations(sorted(self.live), self.params.k):
            f = self.collector_flow(combo)
            if f < worst_flow:
                worst, worst_flow = combo, f
        return worst, worst_flow


def event_from_plan(plan, newcomer_id: int, provider_ids: Sequence[int]) -> RepairEvent:
    """Translate an overlay-indexed RepairPlan (0 = newcomer, 1..d = providers)
    into a storage-id RepairEvent."""
    idmap = {0: newcomer_id}
    for i, pid in enumerate(provider_ids, start=1):
        idmap[i] = pid
    parent = {idmap[u]: idmap[p] for u, p in plan.parent.items()}
    flows = {(idmap[u], idmap[p]): f for (u, p), f in plan.flows.items()}
    return RepairEvent(newcomer=newcomer_id, parent=parent, flows=flows)
