"""Core contribution of the paper: regeneration-time-minimizing repair
planning for erasure-coded state over heterogeneous links.

Schemes (all return :class:`~repro.core.params.RepairPlan`):

* ``plan_star`` — conventional uniform-traffic star [3] (baseline);
* ``plan_fr``   — flexible repair traffic on the star (Section III);
* ``plan_tr``   — tree topology, uniform traffic (Section IV, Algorithm 1);
* ``plan_ftr``  — flexible traffic on a searched tree (Section V, Algorithm 2);
* ``plan_shah`` — the (beta_max, gamma) scheme of [6] (related-work baseline);
* ``plan_rctree`` — RCTREE [7], the MDS-violating prior scheme (Appendix A);
* ``plan_ort_uniform`` / ``plan_ort_flexible`` — exact brute force for small d.

``InfoFlowGraph`` verifies the MDS property of any repair history by
max-flow (Lemma 1); ``FeasibleRegion`` encodes Theorem-1 regions.

All schemes are entries in the capability-aware registry of
:mod:`repro.core.api`; ``plan(net, params, scheme)`` and
``plan_many(nets, params, scheme)`` are the unified entry points that own
engine resolution (scalar vs batched) and kwarg forwarding.  The legacy
``SCHEMES`` / ``BATCHED_SCHEMES`` dicts and ``plan_batch`` remain as
registry-backed deprecation shims.
"""
from .params import (CodeParams, OverlayNetwork, RepairPlan, Edge,
                     mbr_point, msr_point, plan_time, tree_flows, uniform_beta)
from .regions import (FeasibleRegion, heuristic_region, msr_region, sigma,
                      shah_region_thresholds, theorem6_example, uniform_point)
from .star import fr_closed_form_msr, plan_fr, plan_shah, plan_star
from .tree import plan_tr, tree_time_uniform
from .ftr import eval_tree, plan_ftr
from .ort import iter_rooted_trees, plan_ort_flexible, plan_ort_uniform
from .rctree import plan_rctree
from .infoflow import InfoFlowGraph, RepairEvent, event_from_plan

__all__ = [
    "CodeParams", "OverlayNetwork", "RepairPlan", "Edge", "FeasibleRegion",
    "InfoFlowGraph", "RepairEvent", "event_from_plan",
    "eval_tree", "fr_closed_form_msr", "heuristic_region", "iter_rooted_trees",
    "mbr_point", "msr_point", "msr_region", "plan_fr", "plan_ftr",
    "plan_ort_flexible", "plan_ort_uniform", "plan_rctree", "plan_shah",
    "plan_star", "plan_time", "plan_tr", "shah_region_thresholds", "sigma",
    "theorem6_example", "tree_flows", "tree_time_uniform", "uniform_beta",
    "uniform_point",
]

from .extensions import (plan_multi_failures, store_and_forward_time,
                         streaming_time_with_latency)
__all__ += ["plan_multi_failures", "store_and_forward_time",
            "streaming_time_with_latency"]

from .batched import (BatchPlanResult, caps_tensor, minmax_time_star_batch,
                      plan_batch, plan_fr_batch, plan_ftr_batch,
                      plan_shah_batch, plan_star_batch, plan_tr_batch,
                      plans_from_batch, tree_optimal_time_batch)
__all__ += ["BatchPlanResult", "caps_tensor", "minmax_time_star_batch",
            "plan_batch", "plan_fr_batch", "plan_ftr_batch",
            "plan_shah_batch", "plan_star_batch", "plan_tr_batch",
            "plans_from_batch", "tree_optimal_time_batch"]

# The unified planner API (scheme registry + plan()/plan_many dispatchers);
# SCHEMES / BATCHED_SCHEMES live on as registry-backed deprecation shims.
from .api import (BATCHED_SCHEMES, SCHEMES, SchemeSpec, get_scheme, plan,
                  plan_many, register_scheme, scheme_names, schemes,
                  unregister_scheme)
__all__ += ["BATCHED_SCHEMES", "SCHEMES", "SchemeSpec", "get_scheme", "plan",
            "plan_many", "register_scheme", "scheme_names", "schemes",
            "unregister_scheme"]

from .witness import (level_cut, level_cut_batch, min_traffic_batch,
                      tree_traffic_batch)
__all__ += ["level_cut", "level_cut_batch", "min_traffic_batch",
            "tree_traffic_batch"]
