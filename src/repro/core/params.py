"""Code parameters, overlay networks and repair plans.

Units: data is measured in *blocks* (the paper's quantum, Section II); link
capacities are in blocks/second.  All of ``M``, ``alpha``, ``beta`` are block
counts and may be fractional during planning (Section III-C: fractional
solutions are rounded up by the executor; tests check rounding keeps MDS).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

Edge = Tuple[int, int]  # (child u, parent v): data flows u -> v toward root


@functools.lru_cache(maxsize=4096)
def uniform_beta(M: float, k: int, d: int, alpha: float) -> float:
    """Per-provider repair traffic of the conventional scheme (Theorem 3).

    The smallest b >= 0 with  sum_{j=1..k} min((d-k+j)*b, alpha) = M.
    Exists iff k*alpha >= M and d >= k.  Cached: the planners evaluate this
    once per edge comparison on the Monte-Carlo hot path.
    """
    if d < k:
        raise ValueError(f"need d >= k, got d={d} k={k}")
    if k * alpha < M - 1e-9:
        raise ValueError(f"k*alpha={k * alpha} < M={M}: file cannot be stored")
    # Term j saturates (== alpha) once b >= alpha/(d-k+j); larger j saturates
    # first.  Try s = number of saturated terms (the s largest j's).
    for s in range(k + 1):
        mult = sum(d - k + j for j in range(1, k - s + 1))  # unsaturated terms
        if mult == 0:
            b = alpha / max(d - k + 1, 1)
            if s * alpha >= M - 1e-9:
                return b
            continue
        b = (M - s * alpha) / mult
        if b < -1e-12:
            continue
        b = max(b, 0.0)
        # consistency: exactly the top-s terms saturated at this b
        ok = True
        for j in range(1, k + 1):
            sat = (d - k + j) * b >= alpha * (1 - 1e-12)
            should_sat = j > k - s
            # allow boundary equality to count either way
            if sat != should_sat and abs((d - k + j) * b - alpha) > 1e-9 * max(alpha, 1.0):
                ok = False
                break
        if ok:
            return b
    raise ArithmeticError("uniform_beta: no consistent piecewise solution found")


def msr_point(M: float, k: int, d: int) -> Tuple[float, float]:
    """(alpha, beta) at the minimum-storage regenerating point."""
    alpha = M / k
    return alpha, alpha / (d - k + 1)


def mbr_point(M: float, k: int, d: int) -> Tuple[float, float]:
    """(alpha, beta) at the minimum-bandwidth regenerating point [3]."""
    beta = 2.0 * M / (k * (2 * d - k + 1))
    return d * beta, beta


@dataclasses.dataclass(frozen=True)
class CodeParams:
    """(n, k) MDS code regenerated from d providers."""

    n: int
    k: int
    d: int
    M: float              # file size in blocks
    alpha: float          # blocks stored per node

    def __post_init__(self):
        if not (self.k <= self.d <= self.n - 1):
            raise ValueError(f"need k <= d <= n-1: n={self.n} k={self.k} d={self.d}")
        if self.alpha < self.M / self.k - 1e-9:
            raise ValueError("alpha below MSR point")

    @property
    def beta(self) -> float:
        """Uniform per-provider repair traffic of the conventional scheme."""
        return uniform_beta(self.M, self.k, self.d, self.alpha)

    @property
    def is_msr(self) -> bool:
        return abs(self.alpha - self.M / self.k) <= 1e-9 * max(self.M, 1.0)

    @classmethod
    def msr(cls, n: int, k: int, d: int, M: float) -> "CodeParams":
        return cls(n=n, k=k, d=d, M=M, alpha=M / k)

    @classmethod
    def mbr(cls, n: int, k: int, d: int, M: float) -> "CodeParams":
        alpha, _ = mbr_point(M, k, d)
        return cls(n=n, k=k, d=d, M=M, alpha=alpha)


class OverlayNetwork:
    """Complete directed overlay over the newcomer (node 0) and d providers.

    ``cap[u][v]`` is the available bandwidth u -> v in blocks/sec.  Node 0 is
    always the newcomer; nodes 1..d are providers (paper Section II).
    """

    def __init__(self, cap: Sequence[Sequence[float]]):
        self.cap = [list(row) for row in cap]
        self.num_nodes = len(self.cap)
        if any(len(row) != self.num_nodes for row in self.cap):
            raise ValueError("capacity matrix must be square")

    @property
    def d(self) -> int:
        return self.num_nodes - 1

    def c(self, u: int, v: int) -> float:
        return self.cap[u][v]

    def direct_caps(self) -> List[float]:
        """Provider -> newcomer capacities c_i, i = 1..d."""
        return [self.cap[i][0] for i in range(1, self.num_nodes)]

    @classmethod
    def star_only(cls, direct: Sequence[float], cross: float = 0.0) -> "OverlayNetwork":
        """Overlay with given provider->newcomer capacities; all
        provider<->provider links set to ``cross``."""
        d = len(direct)
        cap = [[cross] * (d + 1) for _ in range(d + 1)]
        for i, c in enumerate(direct, start=1):
            cap[i][0] = c
        for i in range(d + 1):
            cap[i][i] = 0.0
        return cls(cap)

    @classmethod
    def from_edges(cls, d: int, edges: Dict[Edge, float], default: float = 0.0) -> "OverlayNetwork":
        cap = [[default] * (d + 1) for _ in range(d + 1)]
        for i in range(d + 1):
            cap[i][i] = 0.0
        for (u, v), c in edges.items():
            cap[u][v] = c
        return cls(cap)


@dataclasses.dataclass
class RepairPlan:
    """A fully-specified single-newcomer regeneration.

    ``parent[u]`` for u in 1..d gives the tree edge u -> parent[u] (parent 0
    is the newcomer).  ``betas[i-1]`` is the number of coded blocks
    *generated* by provider i from its local alpha blocks.  ``flows[(u,v)]``
    is the number of blocks transmitted on tree edge (u, v).
    """

    scheme: str
    params: CodeParams
    parent: Dict[int, int]
    betas: List[float]
    flows: Dict[Edge, float]
    time: float
    lower_bound: Optional[float] = None  # optional certificate (e.g. LP bound)

    @property
    def total_traffic(self) -> float:
        return sum(self.flows.values())

    def subtree_nodes(self, u: int) -> List[int]:
        children: Dict[int, List[int]] = {}
        for c_, p in self.parent.items():
            children.setdefault(p, []).append(c_)
        out, stack = [], [u]
        while stack:
            x = stack.pop()
            out.append(x)
            stack.extend(children.get(x, []))
        return out

    def validate(self, net: OverlayNetwork, tol: float = 1e-6) -> None:
        """Structural checks: it is a tree rooted at 0; flows/time consistent."""
        d = self.params.d
        assert set(self.parent.keys()) == set(range(1, d + 1)), "every provider needs a parent"
        # acyclicity / rooted at 0
        for u in range(1, d + 1):
            seen, x = set(), u
            while x != 0:
                assert x not in seen, f"cycle through {x}"
                seen.add(x)
                x = self.parent[x]
        # flow consistency with betas: f(u, p(u)) = min(sum_{x in S(u)} beta_x, alpha)
        for u in range(1, d + 1):
            sub = self.subtree_nodes(u)
            expect = min(sum(self.betas[x - 1] for x in sub), self.params.alpha)
            got = self.flows[(u, self.parent[u])]
            assert abs(got - expect) <= tol * max(1.0, expect), (
                f"flow on ({u},{self.parent[u]}): got {got}, expect {expect}")
        # reported time
        t = plan_time(self, net)
        assert t <= self.time * (1 + 1e-6) + tol, f"time understated: {self.time} < {t}"


def plan_time(plan: RepairPlan, net: OverlayNetwork) -> float:
    """Regeneration time  max f(u,v)/c(u,v)  (store-and-forward, paper eq. in §II)."""
    t = 0.0
    for (u, v), f in plan.flows.items():
        if f <= 1e-12:
            continue
        c = net.c(u, v)
        if c <= 0:
            return math.inf
        t = max(t, f / c)
    return t


def tree_flows(parent: Dict[int, int], betas: Sequence[float], alpha: float) -> Dict[Edge, float]:
    """Per-edge flows for a tree with per-provider generation ``betas``.

    f(u, parent(u)) = min(sum of betas in the subtree rooted at u, alpha) —
    interior nodes re-encode down to alpha blocks when they hold more
    (Section V-A).
    """
    children: Dict[int, List[int]] = {}
    for u, p in parent.items():
        children.setdefault(p, []).append(u)
    flows: Dict[Edge, float] = {}
    subtotal: Dict[int, float] = {}

    def visit(u: int) -> float:
        s = betas[u - 1]
        for c_ in children.get(u, []):
            s += min(visit(c_), alpha)
        subtotal[u] = s
        return s

    for r in children.get(0, []):
        visit(r)
    for u, p in parent.items():
        flows[(u, p)] = min(subtotal[u], alpha)
    return flows
