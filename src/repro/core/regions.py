"""Feasible regions of repair-traffic vectors (paper Section III).

A *feasible region* D subset R^d is a set of repair-bandwidth vectors
beta = (beta_1..beta_d) such that the MDS property is maintained whenever
every repair round picks beta from D (min-cut condition, eq. (3)).

Theorem 1: a maximal region is  {beta : sigma_j(beta) >= x_j, j=1..k}  with
0 <= x_1 <= ... <= x_k <= alpha and sum x_j >= M, where sigma_j(beta) is the
sum of the (d-k+j) smallest components of beta.

Theorem 2 (MSR, alpha = M/k): the unique maximum region is
{beta : sigma_1(beta) >= M/k}.

Section III-C (non-MSR): no maximum region exists (Theorem 6); the paper's
heuristic region is  {beta : sigma_j(beta) >= min((d-k+j)*beta_u, alpha)}
with beta_u the uniform traffic of the conventional scheme — it always
contains the uniform point, so flexible repair is never worse than STAR.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .params import CodeParams


def sigma(j: int, beta: Sequence[float], k: int, d: int) -> float:
    """sigma_j(beta): sum of the (d-k+j) smallest components (1 <= j <= k)."""
    m = d - k + j
    if not (1 <= j <= k) or m > len(beta):
        raise ValueError(f"sigma_{j} undefined for d={d} k={k} len={len(beta)}")
    return sum(sorted(beta)[:m])


def sigma_all_batch(beta: np.ndarray, k: int, d: int) -> np.ndarray:
    """All sigma_j at once over a batch: ``beta`` is (..., d), the result is
    (..., k) with entry j-1 = sum of the (d-k+j) smallest components.

    One sort + cumsum per batch element replaces k re-sorted Python sums —
    the vectorized core of the Theorem-1 feasibility check.
    """
    s = np.sort(beta, axis=-1)
    cs = np.cumsum(s, axis=-1)
    idx = np.arange(d - k, d)  # m_j - 1 for j = 1..k
    return cs[..., idx]


@dataclasses.dataclass(frozen=True)
class FeasibleRegion:
    """Maximal region in Theorem-1 form: sigma_j(beta) >= x[j-1], j = 1..k."""

    k: int
    d: int
    x: tuple  # length k, non-decreasing

    def __post_init__(self):
        if len(self.x) != self.k:
            raise ValueError("need one threshold per j = 1..k")
        for a, b in zip(self.x, self.x[1:]):
            if a > b + 1e-9:
                raise ValueError(f"thresholds must be non-decreasing: {self.x}")

    def contains(self, beta: Sequence[float], tol: float = 1e-9) -> bool:
        return all(
            sigma(j, beta, self.k, self.d) >= self.x[j - 1] - tol
            for j in range(1, self.k + 1)
        )

    def contains_batch(self, beta: np.ndarray, tol: float = 1e-9) -> np.ndarray:
        """Vectorized ``contains``: ``beta`` is (..., d), returns (...,) bool."""
        sig = sigma_all_batch(np.asarray(beta, dtype=np.float64), self.k, self.d)
        return np.all(sig >= np.asarray(self.x) - tol, axis=-1)

    def mincut(self, alpha: float) -> float:
        """MC(D, alpha) from eq. (3): sum_j min(min_{beta in D} sigma_j, alpha).

        For a Theorem-1-form region, min over D of sigma_j is exactly x_j
        (each constraint is tight somewhere on the boundary).
        """
        return sum(min(xj, alpha) for xj in self.x)

    def is_feasible(self, params: CodeParams, tol: float = 1e-9) -> bool:
        """Min-cut condition MC(D, alpha) >= M."""
        return self.mincut(params.alpha) >= params.M - tol


def msr_region(params: CodeParams) -> FeasibleRegion:
    """Theorem 2: the maximum region at MSR — only sigma_1 >= M/k binds.

    Encoded in Theorem-1 form with x_j = alpha for j >= 2 (implied by
    sigma_j >= sigma_1 and the alpha cap; this is the same set).
    """
    if not params.is_msr:
        raise ValueError("msr_region requires alpha == M/k")
    a = params.M / params.k
    return FeasibleRegion(k=params.k, d=params.d, x=tuple([a] * params.k))


def heuristic_region(params: CodeParams) -> FeasibleRegion:
    """Section III-C heuristic region for any alpha >= M/k.

    x_j = min((d-k+j) * beta_uniform, alpha).  Contains the uniform point;
    reduces to the Theorem-2 maximum region at MSR (where (d-k+1)*beta =
    alpha, so every threshold is alpha... and sigma_j >= sigma_1 makes the
    j = 1 constraint the binding one).
    """
    b = params.beta
    x = tuple(
        min((params.d - params.k + j) * b, params.alpha)
        for j in range(1, params.k + 1)
    )
    return FeasibleRegion(k=params.k, d=params.d, x=x)


def uniform_point(params: CodeParams) -> List[float]:
    """The conventional scheme's beta = (beta, ..., beta); always in the
    heuristic region (paper Section III-C)."""
    return [params.beta] * params.d


def shah_region_thresholds(params: CodeParams, beta_max: float) -> float:
    """Baseline [6] (Shah et al.): beta_i in [0, beta_max], sum beta_i >= gamma.

    Returns the smallest gamma such that the box-simplex set is a feasible
    region.  Worst case of sigma_j over the set puts beta_max into the k - j
    *largest* coordinates, so min sigma_j = gamma - (k - j) * beta_max and we
    need that >= min((d-k+j) beta_u, alpha) for all j.
    """
    b = params.beta
    gamma = 0.0
    for j in range(1, params.k + 1):
        need = min((params.d - params.k + j) * b, params.alpha)
        gamma = max(gamma, need + (params.k - j) * beta_max)
    return gamma


def theorem6_example():
    """The two incomparable maximal regions of Example 1 (n=5, k=3, d=4,
    M=12, alpha=6) used in tests to reproduce the no-maximum-region result."""
    p = CodeParams(n=5, k=3, d=4, M=12, alpha=6)
    d1 = FeasibleRegion(k=3, d=4, x=(1, 5, 6))
    d2 = FeasibleRegion(k=3, d=4, x=(2, 4, 6))
    return p, d1, d2
