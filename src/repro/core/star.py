"""Star-topology schemes: conventional STAR [3] and Flexible Regeneration
(FR, paper Section III)."""
from __future__ import annotations

from typing import Dict, List

from .params import CodeParams, OverlayNetwork, RepairPlan, tree_flows
from .regions import FeasibleRegion, heuristic_region, msr_region
from . import lp


def _star_parent(d: int) -> Dict[int, int]:
    return {i: 0 for i in range(1, d + 1)}


def _star_time(flows: Dict, caps: List[float], d: int) -> float:
    """max_i f(i,0)/c_i with inf on nonpositive links (shared by every star
    planner; repro.core.batched vectorizes the same expression)."""
    if not d:
        return 0.0
    return max((flows[(i, 0)] / caps[i - 1]) if caps[i - 1] > 0 else float("inf")
               for i in range(1, d + 1))


def plan_star(net: OverlayNetwork, params: CodeParams) -> RepairPlan:
    """Conventional regeneration: uniform beta from every provider straight
    to the newcomer (Dimakis et al. [3])."""
    d = params.d
    b = params.beta
    betas = [b] * d
    parent = _star_parent(d)
    flows = tree_flows(parent, betas, params.alpha)
    time = _star_time(flows, net.direct_caps(), d)
    return RepairPlan("star", params, parent, betas, flows, time)


def fr_closed_form_msr(caps: List[float], params: CodeParams) -> List[float]:
    """Closed-form optimum of problem (4) at MSR (Section III-B).

    Sort capacities ascending; the d-k+1 slowest providers carry traffic
    proportional to their capacity, the rest match the (d-k+1)-th:
        beta_j = c_j * M / (k * sum_{i<=d-k+1} c_i)   for j <= d-k+1
        beta_j = beta_{d-k+1}                          otherwise.
    """
    d, k, M = params.d, params.k, params.M
    order = sorted(range(d), key=lambda i: caps[i])
    m = d - k + 1
    denom = sum(caps[order[i]] for i in range(m))
    betas = [0.0] * d
    if denom <= 0:
        raise ZeroDivisionError("the d-k+1 slowest links have zero capacity")
    for rank, i in enumerate(order):
        if rank < m:
            betas[i] = caps[i] * M / (k * denom)
        else:
            betas[i] = caps[order[m - 1]] * M / (k * denom)
    return betas


def plan_fr(net: OverlayNetwork, params: CodeParams,
            region: FeasibleRegion | None = None,
            minimize_traffic: bool = True,
            witness: str = "exact") -> RepairPlan:
    """Flexible Regeneration: star topology, non-uniform beta chosen from the
    (maximum at MSR / heuristic otherwise) feasible region by solving the
    min-max problem (1).

    ``witness`` picks the traffic-minimal witness engine at the optimal
    time: the exact level-cut oracle (default) or the scipy LP
    (``witness="lp"``, kept as the correctness oracle).
    """
    # eager, like the batched planner: the MSR closed form never consults
    # the witness engine, so a typo would otherwise pass silently
    if witness not in ("exact", "lp"):
        raise ValueError(f"unknown witness engine {witness!r}")
    d = params.d
    caps = net.direct_caps()
    if region is None:
        region = msr_region(params) if params.is_msr else heuristic_region(params)

    if params.is_msr and all(c > 0 for c in caps):
        betas = fr_closed_form_msr(caps, params)
        time = max(betas[i] / caps[i] for i in range(d))
        # cross-check against the bisection optimum (cheap, exact)
        t_star = lp.minmax_time_star(caps, region, params.alpha)
        if t_star < time * (1 - 1e-9):  # pragma: no cover - closed form is optimal
            time = t_star
            betas = lp.min_traffic_at_time(t_star, caps, region, params.alpha,
                                           witness=witness)
    else:
        time = lp.minmax_time_star(caps, region, params.alpha)
        if minimize_traffic:
            betas = lp.min_traffic_at_time(time, caps, region, params.alpha,
                                           witness=witness)
        else:
            betas = [min(time * c, params.alpha) for c in caps]

    parent = _star_parent(d)
    flows = tree_flows(parent, betas, params.alpha)
    t = _star_time(flows, caps, d)
    return RepairPlan("fr", params, parent, betas, flows, max(t, 0.0),
                      lower_bound=time)


def plan_shah(net: OverlayNetwork, params: CodeParams,
              beta_max: float | None = None) -> RepairPlan:
    """Baseline [6] (Shah et al.): beta_i in [0, beta_max], sum beta_i >= gamma.

    With gamma chosen minimally for the MDS property (see
    ``regions.shah_region_thresholds``).  Greedy water-filling from the
    fastest links minimizes the max transfer time over the box-simplex set.
    """
    from .regions import shah_region_thresholds

    d = params.d
    caps = net.direct_caps()
    if beta_max is None:
        beta_max = params.alpha  # most permissive per-provider cap
    gamma = shah_region_thresholds(params, beta_max)

    # bisection on t: achievable iff sum_i min(t*c_i, beta_max) >= gamma
    lo, hi = 0.0, 1.0
    def tot(t: float) -> float:
        return sum(min(t * c, beta_max) for c in caps)
    while tot(hi) < gamma:
        hi *= 2
        if hi > 1e18:
            return RepairPlan("shah", params, _star_parent(d), [0.0] * d, {},
                              float("inf"))
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if tot(mid) >= gamma:
            hi = mid
        else:
            lo = mid
    t = hi
    betas = [min(t * c, beta_max) for c in caps]
    # trim surplus from the slowest contributors (they set the clock)
    surplus = sum(betas) - gamma
    for i in sorted(range(d), key=lambda i: caps[i]):
        if surplus <= 0:
            break
        cut = min(surplus, betas[i])
        betas[i] -= cut
        surplus -= cut
    parent = _star_parent(d)
    flows = tree_flows(parent, betas, params.alpha)
    time = _star_time(flows, caps, d)
    return RepairPlan("shah", params, parent, betas, flows, time)
