"""Cluster state: node health, rack placement, and the live capacity matrix.

Node identity is a *slot*: when slot ``x`` fails, a replacement host takes
the same slot, so the directed capacity matrix keeps its shape for the whole
simulation and plans map onto physical links by plain index pairs.

States form a 3-way machine per slot::

    HEALTHY --fail--> FAILED (queued) --start_repair--> REPAIRING
       ^                                                    |
       +---------------- complete_repair -------------------+

A REPAIRING slot that loses a provider reverts to FAILED (requeued by the
simulator).  ``unavailable`` counts FAILED + REPAIRING slots — an (n, k) MDS
code loses data when that exceeds n - k, i.e. fewer than k slots are
HEALTHY.

A FAILED slot is not necessarily empty: with partial-progress carryover the
replacement host keeps the blocks it already received before the abort (the
simulator's queue carries the per-link bank), so FAILED -> REPAIRING may
resume from banked work rather than from zero.  Health state and progress
state are deliberately separate — this class only answers "who is up".
"""
from __future__ import annotations

from typing import List, Set

import numpy as np

HEALTHY, FAILED, REPAIRING = 0, 1, 2


class ClusterState:
    """n storage slots over a mutable directed capacity matrix."""

    def __init__(self, caps: np.ndarray, rack_size: int = 0):
        caps = np.asarray(caps, dtype=np.float64)
        if caps.ndim != 2 or caps.shape[0] != caps.shape[1]:
            raise ValueError("caps must be a square (n, n) matrix")
        if (caps < 0).any():
            raise ValueError("link capacities must be non-negative")
        self.caps = caps.copy()
        np.fill_diagonal(self.caps, 0.0)
        self.n = caps.shape[0]
        self.rack_size = rack_size
        self.state = np.zeros(self.n, dtype=np.int8)

    # -- placement ----------------------------------------------------------

    def rack_of(self, node: int) -> int:
        return node // self.rack_size if self.rack_size > 0 else 0

    def rack_peers(self, node: int) -> List[int]:
        if self.rack_size <= 0:
            return []
        r = self.rack_of(node)
        return [x for x in range(self.n)
                if x != node and self.rack_of(x) == r]

    # -- health -------------------------------------------------------------

    def healthy_nodes(self) -> List[int]:
        return [int(x) for x in np.flatnonzero(self.state == HEALTHY)]

    def healthy_set(self) -> Set[int]:
        """Same membership as :meth:`healthy_nodes`, O(1) lookups — for
        filtering surviving providers and torn-down read endpoints."""
        return set(self.healthy_nodes())

    @property
    def num_healthy(self) -> int:
        return int((self.state == HEALTHY).sum())

    @property
    def num_unavailable(self) -> int:
        return self.n - self.num_healthy

    def fail(self, node: int) -> None:
        if self.state[node] != HEALTHY:
            raise ValueError(f"node {node} is not healthy")
        self.state[node] = FAILED

    def start_repair(self, node: int) -> None:
        if self.state[node] != FAILED:
            raise ValueError(f"node {node} is not awaiting repair")
        self.state[node] = REPAIRING

    def abort_repair(self, node: int) -> None:
        if self.state[node] != REPAIRING:
            raise ValueError(f"node {node} is not under repair")
        self.state[node] = FAILED

    def complete_repair(self, node: int) -> None:
        if self.state[node] != REPAIRING:
            raise ValueError(f"node {node} is not under repair")
        self.state[node] = HEALTHY
