"""Cluster state: node health, rack placement, and the live capacity matrix.

Node identity is a *slot*: when slot ``x`` fails, a replacement host takes
the same slot, so the directed capacity matrix keeps its shape for the whole
simulation and plans map onto physical links by plain index pairs.

States form a 3-way machine per slot::

    HEALTHY --fail--> FAILED (queued) --start_repair--> REPAIRING
       ^                                                    |
       +---------------- complete_repair -------------------+

A REPAIRING slot that loses a provider reverts to FAILED (requeued by the
simulator).  ``unavailable`` counts FAILED + REPAIRING slots — an (n, k) MDS
code loses data when that exceeds n - k, i.e. fewer than k slots are
HEALTHY.

A FAILED slot is not necessarily empty: with partial-progress carryover the
replacement host keeps the blocks it already received before the abort (the
simulator's queue carries the per-link bank), so FAILED -> REPAIRING may
resume from banked work rather than from zero.  Health state and progress
state are deliberately separate — this class only answers "who is up".
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

HEALTHY, FAILED, REPAIRING = 0, 1, 2


class ClusterState:
    """n storage slots over a mutable directed capacity matrix."""

    def __init__(self, caps: np.ndarray, rack_size: int = 0):
        caps = np.asarray(caps, dtype=np.float64)
        if caps.ndim != 2 or caps.shape[0] != caps.shape[1]:
            raise ValueError("caps must be a square (n, n) matrix")
        if (caps < 0).any():
            raise ValueError("link capacities must be non-negative")
        self.caps = caps.copy()
        np.fill_diagonal(self.caps, 0.0)
        self.n = caps.shape[0]
        self.rack_size = rack_size
        self.state = np.zeros(self.n, dtype=np.int8)
        # incremental health bookkeeping (ISSUE 8): the healthy count and
        # membership only change on fail / complete_repair (start/abort
        # toggle FAILED <-> REPAIRING, both unhealthy), so both are kept
        # as caches invalidated exactly there instead of rescanning
        # ``state`` on every event epoch
        self._num_healthy = self.n
        self._healthy_list: Optional[List[int]] = None
        self._healthy_set: Optional[Set[int]] = None

    # -- placement ----------------------------------------------------------

    def rack_of(self, node: int) -> int:
        return node // self.rack_size if self.rack_size > 0 else 0

    def rack_peers(self, node: int) -> List[int]:
        if self.rack_size <= 0:
            return []
        r = self.rack_of(node)
        return [x for x in range(self.n)
                if x != node and self.rack_of(x) == r]

    # -- health -------------------------------------------------------------

    def healthy_nodes(self) -> List[int]:
        """Ascending healthy slot ids (cached; treat as read-only)."""
        if self._healthy_list is None:
            self._healthy_list = [
                int(x) for x in np.flatnonzero(self.state == HEALTHY)]
        return self._healthy_list

    def healthy_set(self) -> Set[int]:
        """Same membership as :meth:`healthy_nodes`, O(1) lookups — for
        filtering surviving providers and torn-down read endpoints
        (cached; treat as read-only)."""
        if self._healthy_set is None:
            self._healthy_set = set(self.healthy_nodes())
        return self._healthy_set

    @property
    def num_healthy(self) -> int:
        return self._num_healthy

    @property
    def num_unavailable(self) -> int:
        return self.n - self._num_healthy

    def _health_changed(self, delta: int) -> None:
        self._num_healthy += delta
        self._healthy_list = None
        self._healthy_set = None

    def fail(self, node: int) -> None:
        if self.state[node] != HEALTHY:
            raise ValueError(f"node {node} is not healthy")
        self.state[node] = FAILED
        self._health_changed(-1)

    def start_repair(self, node: int) -> None:
        if self.state[node] != FAILED:
            raise ValueError(f"node {node} is not awaiting repair")
        self.state[node] = REPAIRING

    def abort_repair(self, node: int) -> None:
        if self.state[node] != REPAIRING:
            raise ValueError(f"node {node} is not under repair")
        self.state[node] = FAILED

    def complete_repair(self, node: int) -> None:
        if self.state[node] != REPAIRING:
            raise ValueError(f"node {node} is not under repair")
        self.state[node] = HEALTHY
        self._health_changed(+1)
