"""Fleet simulator: event-driven cluster regeneration under contention.

The paper evaluates one regeneration at a time on one sampled overlay; a
production fleet repairs continuously, and concurrent regenerations share
the same heterogeneous links.  This package simulates an n-slot
erasure-coded cluster over simulated time — Poisson (optionally
rack-correlated) failures, a repair queue, fair-share link contention,
pluggable per-repair scheme policies backed by the batched planning
engine — and reports fleet metrics (backlog, p50/p99 regeneration time
under contention, window of vulnerability, MTTDL estimate) that
single-repair Monte Carlo cannot produce.  See src/README.md for the
architecture and ``benchmarks/fleet_scale.py`` for the sweep driver.
"""
from .cluster import ClusterState, FAILED, HEALTHY, REPAIRING
from .dataplane import DataPlane, ReadTrace, generate_trace
from .ensemble import (ClusterEnsemble, bootstrap_cis, cluster_seed,
                       pool_metrics)
from .events import Event, EventQueue
from .metrics import FleetMetrics
from .policy import FixedPolicy, FlexiblePolicy, RepairPolicy, make_policy
from .scenario import (SCENARIOS, Scenario, capacity_weather,
                       flaky_providers, foggy_estimates, hot_reads,
                       mitigated, rack_bursts, steady, stragglers, tiered,
                       tiered_capacities)
from .sharing import ActiveRepair, LinkShareModel, apply_credit, plan_links
from .sim import FleetSimulator, QueuedRepair, simulate

__all__ = [
    "ActiveRepair", "ClusterEnsemble", "ClusterState", "DataPlane",
    "Event", "EventQueue", "FAILED", "FleetMetrics", "FleetSimulator",
    "FixedPolicy", "FlexiblePolicy", "HEALTHY", "LinkShareModel",
    "QueuedRepair", "REPAIRING", "ReadTrace", "RepairPolicy", "SCENARIOS",
    "Scenario", "apply_credit", "bootstrap_cis", "capacity_weather",
    "cluster_seed", "flaky_providers", "foggy_estimates", "generate_trace",
    "hot_reads", "make_policy", "mitigated", "plan_links", "pool_metrics",
    "rack_bursts", "simulate", "steady", "stragglers", "tiered",
    "tiered_capacities",
]
