"""Scenario layer: failure processes, capacity models, and load, composed.

A :class:`Scenario` is pure configuration — everything stochastic is drawn
inside the simulator from named child streams of one root seed, so a
scenario replayed with the same seed is bitwise reproducible.

Capacity models reuse the repo's existing samplers rather than inventing
new ones: ``repro.storage.capacities.uniform_matrix`` gives the paper's
PlanetLab-style i.i.d. regime at cluster scale, and ``tiered_capacities``
wraps ``repro.ft.topology.Fleet`` so the TPU-fleet two-tier (intra-pod /
cross-pod DCN + stragglers) topology drives fleet simulations too.

``SCENARIOS`` is the library the benchmarks sweep: steady-state Poisson
churn, rack-correlated failure bursts, capacity weather (periodic
background-traffic shocks), and degraded-read pressure.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.storage.capacities import ClusterCapSampler, uniform_matrix

from .dataplane import ReadTrace


def tiered_capacities(num_pods: int = 2, hosts_per_pod: int = 0,
                      block_mb: float = 64.0,
                      straggler_fraction: float = 0.05,
                      ) -> ClusterCapSampler:
    """TPU-fleet two-tier capacities via ``repro.ft.topology.Fleet``.

    ``hosts_per_pod = 0`` derives the pod size from the cluster size n at
    sample time (ceil(n / num_pods)).  The Fleet's straggler assignment is
    seeded from the scenario's capacity stream, keeping determinism.
    """

    def sample(rng: np.random.Generator, n: int) -> np.ndarray:
        from repro.ft import Fleet, FleetConfig

        hpp = hosts_per_pod or -(-n // num_pods)
        fleet = Fleet(FleetConfig(num_pods=num_pods, hosts_per_pod=hpp,
                                  straggler_fraction=straggler_fraction),
                      seed=int(rng.integers(1 << 31)))
        return np.asarray(
            fleet.capacity_matrix(list(range(n)), block_mb=block_mb, rng=rng))

    return sample


# (failed slot, healthy nodes, rng) -> provider ids; None = uniform sample
ProviderPicker = Callable[[int, List[int], np.random.Generator], List[int]]

# (time, node) pairs injected on top of / instead of the Poisson process
InjectedFailure = Tuple[float, int]

# (time, node, factor, duration): at ``time`` the node's outgoing link
# rates are multiplied by ``factor`` in [0, 1) (0.0 = full stall) for
# ``duration`` seconds — deterministic straggler injections for tests,
# on top of / instead of the Markov degrade process
InjectedDegrade = Tuple[float, int, float, float]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Composable description of a fleet workload.

    Rates are per *second* of simulated time; capacities are blocks/sec as
    everywhere else in the repo.
    """

    num_nodes: int
    duration: float
    # -- failure process ----------------------------------------------------
    failure_rate: float = 0.0         # per healthy node, Poisson
    rack_size: int = 0                # 0 = no rack structure
    rack_burst_prob: float = 0.0      # P(failure is a correlated rack burst)
    rack_burst_extra: int = 1         # extra victims per burst, same rack
    failures: Tuple[InjectedFailure, ...] = ()   # deterministic injections
    # -- capacities ---------------------------------------------------------
    capacity_model: ClusterCapSampler = uniform_matrix()
    shock_period: float = 0.0         # 0 = static capacities
    shock_lo: float = 1.0             # per-link multiplier bounds applied to
    shock_hi: float = 1.0             # the base matrix at every shock
    # -- degraded-read load -------------------------------------------------
    read_rate: float = 0.0            # arrivals/sec while any slot is down
    read_duration: float = 1.0        # seconds each read occupies its links
    read_fanin: int = 0               # links per read; 0 = params.k
    # -- repair admission ---------------------------------------------------
    max_concurrent: int = 4
    provider_picker: Optional[ProviderPicker] = None
    # -- repair lifecycle (both OFF by default: the default path reproduces
    #    the pre-PR-3 dynamics bitwise) -------------------------------------
    carryover: bool = False           # keep banked blocks on provider-loss
    #                                   aborts; credit them at re-admission
    migration: bool = False           # offer in-flight repairs a re-plan at
    #                                   capacity-shock / provider-loss epochs
    bank_aware_migration: bool = False    # score every candidate replan by
    #                                   *credited* residual ETA (banked
    #                                   blocks subtracted) instead of taking
    #                                   the policy's nominal-time pick —
    #                                   prefers trees overlapping
    #                                   already-banked links (ISSUE 8)
    # -- plan-vs-reality robustness (ISSUE 6; everything OFF by default:
    #    the default path reproduces the pre-robustness dynamics bitwise) --
    estimate_noise: float = 0.0       # relative noise on each believed
    #                                   capacity snapshot, U[1-e, 1+e]
    estimate_refresh_period: float = 0.0  # seconds between believed-matrix
    #                                   snapshots; 0 = refresh every event
    #                                   epoch (fresh but noisy).  Estimate
    #                                   error is on iff noise > 0 or
    #                                   refresh period > 0
    degrade_rate: float = 0.0         # per-node Poisson rate of silent
    #                                   outgoing-link brownouts
    degrade_mean_duration: float = 0.0    # mean brownout length (Exp)
    degrade_lo: float = 0.0           # brownout rate-multiplier bounds,
    degrade_hi: float = 0.0           # drawn U[lo, hi] in [0, 1)
    degradations: Tuple[InjectedDegrade, ...] = ()  # deterministic stalls
    watchdog_period: float = 0.0      # progress-check interval; 0 = no
    #                                   watchdog (no mitigation)
    watchdog_lag: float = 2.0         # flag a repair once its banked
    #                                   progress falls below 1/lag of the
    #                                   plan-predicted trajectory
    watchdog_retries: int = 3         # straggler evictions per repair
    #                                   before the watchdog gives up
    watchdog_backoff: float = 2.0     # exponential re-check backoff base
    degraded_d: bool = False          # admit with d' in [k, d) helpers when
    #                                   fewer than d are healthy (functional
    #                                   repair stays sound for any d >= k)
    # -- observability (ISSUE 7; OFF by default: with trace off the
    #    simulator allocates no recorder and the default path stays
    #    bitwise identical — tracing is observation, not perturbation) ----
    trace: bool = False               # own a FlightRecorder + link tracer
    trace_capacity: int = 1 << 16     # ring-buffer size (oldest events are
    #                                   overwritten past it, counted as
    #                                   dropped)
    # -- coded data plane (ISSUE 10; OFF by default: with dataplane off the
    #    simulator allocates no coded store, consumes no extra rng, and the
    #    default path stays bitwise identical) -----------------------------
    dataplane: bool = False           # reads become k fragment transfers
    #                                   through fair-share contention
    #                                   (read_duration is ignored) and every
    #                                   completed repair replays its plan on
    #                                   a real RLNC-coded store
    dataplane_block_bytes: float = 64 * 1024 * 1024   # wire bytes per code
    #                                   block (64 MiB, matching the tiered
    #                                   topology's block_mb)
    dataplane_blocks: int = 0         # mini-code file size M for the coded
    #                                   store; 0 = 2k.  Must be divisible by
    #                                   k (integral alpha)
    dataplane_payload_bytes: int = 8  # GF payload bytes per stored block
    dataplane_matmul: str = "auto"    # GF matmul backend for the store:
    #                                   auto | kernel | numpy (see
    #                                   DataPlane._resolve_matmul)
    dataplane_verify: bool = False    # decode-check (can_reconstruct) after
    #                                   every completed repair
    read_trace: Optional[ReadTrace] = None    # open-loop read arrivals
    #                                   (requires dataplane=True); served
    #                                   whenever >= fanin + 1 nodes are
    #                                   healthy, dropped + counted otherwise

    def __post_init__(self):
        if self.num_nodes < 2:
            raise ValueError("need at least 2 nodes")
        if self.duration <= 0:
            raise ValueError("duration must be positive")
        if self.shock_period < 0 or self.failure_rate < 0 or self.read_rate < 0:
            raise ValueError("rates/periods must be non-negative")
        if self.read_duration <= 0:
            raise ValueError("read_duration must be positive")
        if self.shock_lo < 0 or self.shock_hi < self.shock_lo:
            raise ValueError("need 0 <= shock_lo <= shock_hi")
        if self.max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {self.max_concurrent}: "
                f"an admission budget of zero can never start a repair")
        if not 0.0 <= self.rack_burst_prob <= 1.0:
            raise ValueError(
                f"rack_burst_prob must be a probability in [0, 1], got "
                f"{self.rack_burst_prob}")
        if self.rack_burst_extra < 0:
            raise ValueError(
                f"rack_burst_extra must be >= 0, got {self.rack_burst_extra}")
        if self.read_fanin < 0:
            raise ValueError(
                f"read_fanin must be >= 0 (0 = params.k), got "
                f"{self.read_fanin}")
        if not 0.0 <= self.estimate_noise < 1.0:
            raise ValueError(
                f"estimate_noise must be in [0, 1), got "
                f"{self.estimate_noise}: noise >= 1 lets a believed "
                f"capacity hit zero on a live link")
        if self.estimate_refresh_period < 0:
            raise ValueError("estimate_refresh_period must be non-negative")
        if self.degrade_rate < 0:
            raise ValueError("degrade_rate must be non-negative")
        if self.degrade_rate > 0 and self.degrade_mean_duration <= 0:
            raise ValueError(
                "degrade_rate > 0 needs degrade_mean_duration > 0")
        if not 0.0 <= self.degrade_lo <= self.degrade_hi:
            raise ValueError("need 0 <= degrade_lo <= degrade_hi")
        if self.degrade_hi >= 1.0:
            raise ValueError(
                f"degrade factors must stay below 1, got degrade_hi="
                f"{self.degrade_hi}: a multiplier >= 1 is not a brownout")
        for inj in self.degradations:
            t, node, factor, dur = inj
            if not (0.0 <= factor < 1.0) or dur <= 0 or t < 0:
                raise ValueError(
                    f"bad degradation injection {inj}: need time >= 0, "
                    f"factor in [0, 1), duration > 0")
        if self.watchdog_period < 0:
            raise ValueError("watchdog_period must be non-negative")
        if self.watchdog_lag < 1.0:
            raise ValueError(
                f"watchdog_lag must be >= 1, got {self.watchdog_lag}: a "
                f"threshold below 1 flags repairs that are on schedule")
        if self.watchdog_retries < 0:
            raise ValueError("watchdog_retries must be non-negative")
        if self.watchdog_backoff < 1.0:
            raise ValueError(
                f"watchdog_backoff must be >= 1, got "
                f"{self.watchdog_backoff}: a base below 1 re-checks "
                f"faster after every failure")
        if self.trace_capacity < 1:
            raise ValueError(
                f"trace_capacity must be >= 1, got {self.trace_capacity}")
        if self.dataplane and self.read_fanin > self.num_nodes - 1:
            raise ValueError(
                f"read_fanin={self.read_fanin} exceeds the {self.num_nodes - 1} "
                f"possible helpers of an {self.num_nodes}-node cluster: with "
                f"dataplane=True every read needs fanin live sources besides "
                f"its destination, so such a read could never be served")
        if self.read_trace is not None and not self.dataplane:
            raise ValueError(
                "read_trace= is an open-loop data-plane workload and needs "
                "dataplane=True (the legacy phantom-read path is closed-loop "
                "via read_rate and only fires while a slot is down)")
        if self.dataplane:
            if self.dataplane_block_bytes <= 0:
                raise ValueError("dataplane_block_bytes must be positive")
            if self.dataplane_payload_bytes < 1:
                raise ValueError("dataplane_payload_bytes must be >= 1")
            if self.dataplane_blocks < 0:
                raise ValueError("dataplane_blocks must be >= 0 (0 = 2k)")
            if self.dataplane_matmul not in ("auto", "kernel", "numpy"):
                raise ValueError(
                    f"dataplane_matmul must be auto|kernel|numpy, got "
                    f"{self.dataplane_matmul!r}")


# ---------------------------------------------------------------------------
# Scenario library (n-parameterized factories the benchmarks sweep)
# ---------------------------------------------------------------------------

def steady(n: int, failure_rate: float = 2e-3,
           duration: float = 20_000.0) -> Scenario:
    """Steady Poisson churn over static PlanetLab-style capacities."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate)


def rack_bursts(n: int, failure_rate: float = 2e-3,
                duration: float = 20_000.0) -> Scenario:
    """Rack-correlated bursts: 30% of failures take out a rack neighbour
    too, stressing the window-of-vulnerability accounting."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    rack_size=max(n // 4, 2), rack_burst_prob=0.3,
                    rack_burst_extra=1)


def capacity_weather(n: int, failure_rate: float = 2e-3,
                     duration: float = 20_000.0,
                     shock_period: float = 500.0, shock_lo: float = 0.25,
                     cap_lo: float = 10.0, cap_hi: float = 120.0) -> Scenario:
    """Background-traffic weather: every ``shock_period`` seconds each
    link's capacity is rescaled by an independent U[shock_lo, 1]
    multiplier.  The storm knobs (fast, deep shocks over slow links) put
    in-flight repairs under weather that outlives their plans — the
    regime plan migration is for."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    capacity_model=uniform_matrix(cap_lo, cap_hi),
                    shock_period=shock_period, shock_lo=shock_lo,
                    shock_hi=1.0)


def hot_reads(n: int, failure_rate: float = 2e-3,
              duration: float = 20_000.0, dataplane: bool = False,
              read_trace: Optional[ReadTrace] = None,
              dataplane_verify: bool = False) -> Scenario:
    """Degraded-read pressure: while any slot is down, reconstruction reads
    arrive and contend with repairs for the same links.

    With ``dataplane=True`` the reads become real fragment transfers
    (ISSUE 10); passing a ``read_trace`` switches to the open-loop
    trace-driven workload and turns the closed-loop ``read_rate`` off.
    The defaults leave both off, so the golden rows are untouched."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    read_rate=0.0 if read_trace is not None else 0.05,
                    read_duration=20.0, dataplane=dataplane,
                    read_trace=read_trace, dataplane_verify=dataplane_verify)


def tiered(n: int, failure_rate: float = 2e-3,
           duration: float = 20_000.0) -> Scenario:
    """TPU-fleet tiered capacities (repro.ft.topology) under steady churn."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    capacity_model=tiered_capacities())


def flaky_providers(n: int, failure_rate: float = 4e-3,
                    duration: float = 2_500.0) -> Scenario:
    """Provider-loss stress: slow links stretch regenerations onto the same
    timescale as the failure process, so in-flight repairs frequently lose
    a provider mid-transfer — the abort / partial-progress-carryover /
    migration path.  Pair with ``dataclasses.replace(sc, carryover=True,
    migration=True)`` to measure how much of the vulnerability window the
    lifecycle machinery claws back."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    capacity_model=uniform_matrix(0.3, 8.0),
                    max_concurrent=8)


def stragglers(n: int, failure_rate: float = 2e-3,
               duration: float = 4_000.0) -> Scenario:
    """Silent straggler/stall pressure: nodes' outgoing links brown out to
    a U[0, 0.1] multiplier (often a near-full stall) for minutes at a time
    *without the host dying* — the fault class the provider-loss abort
    path cannot see.  Today's simulator silently waits out a stalled link;
    pair with :func:`mitigated` to measure what the watchdog + eviction +
    degraded-d stack claws back."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    capacity_model=uniform_matrix(2.0, 40.0),
                    degrade_rate=1e-3, degrade_mean_duration=400.0,
                    degrade_lo=0.0, degrade_hi=0.1,
                    max_concurrent=8)


def foggy_estimates(n: int, failure_rate: float = 2e-3,
                    duration: float = 4_000.0) -> Scenario:
    """Stale, noisy capacity estimates under weather: the believed matrix
    policies plan against is a U[1-0.35, 1+0.35]-noised snapshot refreshed
    every 300 s, while the true capacities are re-shocked every 120 s —
    predicted and realized ETAs diverge (the plan-error distribution in
    the metrics).  Pair with :func:`mitigated` to let the watchdog rescue
    the worst-planned repairs."""
    return Scenario(num_nodes=n, duration=duration,
                    failure_rate=failure_rate,
                    capacity_model=uniform_matrix(1.0, 30.0),
                    shock_period=120.0, shock_lo=0.2, shock_hi=1.0,
                    estimate_noise=0.35, estimate_refresh_period=300.0,
                    max_concurrent=8)


def mitigated(sc: Scenario, watchdog_period: float = 25.0) -> Scenario:
    """The robustness mitigation stack ON for A/B comparisons: progress
    watchdog (replan -> straggler eviction with retry/backoff), banked-
    block carryover so evictions keep received work, and degraded-d
    admission so repairs stop queueing forever when fewer than d helpers
    are healthy.  The scenario's fault injection knobs are left as-is."""
    return dataclasses.replace(sc, carryover=True, degraded_d=True,
                               watchdog_period=watchdog_period)


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "steady": steady,
    "rack_bursts": rack_bursts,
    "capacity_weather": capacity_weather,
    "hot_reads": hot_reads,
    "tiered": tiered,
    "flaky_providers": flaky_providers,
    "stragglers": stragglers,
    "foggy_estimates": foggy_estimates,
}
