"""Coded data plane for the fleet simulator (ISSUE 10).

With ``Scenario(dataplane=True)`` the fleet stops treating reads and
repairs as phantom fluids and moves *data*:

* a degraded read is ``fanin`` fragment transfers (``params.alpha``
  blocks each, ``dataplane_block_bytes`` per block) whose completion
  time emerges from fair-share link contention — exactly the same
  fluid arithmetic repairs use, through the same ``LinkShareModel`` —
  instead of the fixed ``Scenario.read_duration``;
* every completed repair replays its plan on a real RLNC-coded store
  (``repro.storage.simulator.RlncSimulator.execute_plan``: provider
  encode, interior relay, newcomer regenerate over GF(2^8)), so the
  regenerated node holds actual coded blocks that can be
  decode-verified with ``repro.coding.rlnc.can_reconstruct``;
* bytes on the wire are accounted per link, split into repair vs read
  traffic, and exported through the flight recorder and the
  ``dataplane_*`` rows of ``BENCH_fleet.json``.

Fragment sizing
---------------
The cluster's nominal code stores ``alpha = M/k`` blocks per node; a
degraded read reconstructs the object from ``fanin`` fragments (default
``fanin = params.k``) of ``alpha`` blocks each.  Flows are expressed in
the same block units as link capacities (blocks/sec), so a solo read
over a capacity-``c`` link takes exactly ``alpha / c`` seconds; bytes
are blocks times ``dataplane_block_bytes``.

The coded store is a *miniature* of the cluster code: same ``(n, k,
d)``, but ``M`` scaled down to ``dataplane_blocks`` (default ``2k``)
so GF arithmetic per repair stays cheap.  Completed plans are replayed
with betas/flows ceil-scaled by ``alpha_mini / alpha`` — the Theorem-1
cut constraints are linear in ``beta``, so exact scaling keeps them
satisfied and ``ceil`` only adds slack.  The store draws from its own
rng streams (seeded from the fleet seed), so producing blocks never
perturbs fleet randomness and the traced-equals-untraced invariant
holds unchanged.

Trace-driven reads
------------------
``ReadTrace`` is an open-loop arrival process: either a Poisson
``rate`` (drawn from the fleet's dedicated ``"data"`` rng stream) or a
JSONL ``path`` of ``{"t": <seconds>}`` lines replayed lazily one line
at a time — O(1) memory, so traces of millions of arrivals stream
fine.  ``generate_trace`` writes such a file in vectorized chunks.
Unlike the legacy closed-loop ``read_rate`` (which only fires while a
slot is down), trace arrivals are *served whenever >= fanin + 1
healthy nodes exist* — degraded or not — and are **dropped and
counted** (``reads_dropped``) otherwise; see ``Scenario`` validation.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .sharing import Link, plan_links

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from .metrics import FleetMetrics
    from .scenario import Scenario
    from .sharing import ActiveRepair, LinkShareModel

__all__ = ["DataPlane", "ReadFlow", "ReadTrace", "generate_trace"]

# mixed into the fleet seed for the coded store's own rng streams
_STORE_SALT = 0xDA7A


# ---------------------------------------------------------------------------
# Open-loop read arrival traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReadTrace:
    """Open-loop read workload: a JSONL file of arrivals or a Poisson rate.

    Exactly one of ``path``/``rate`` must be set.  ``path`` points at a
    JSONL file with one ``{"t": <arrival seconds>}`` object per line
    (nondecreasing ``t``); it is replayed lazily line by line, so trace
    files with millions of arrivals never materialize in memory.
    ``rate`` draws exponential gaps from the simulator's dedicated
    ``"data"`` rng stream at generation time.
    """

    path: Optional[str] = None
    rate: float = 0.0

    def __post_init__(self) -> None:
        if (self.path is None) == (self.rate <= 0.0):
            raise ValueError(
                "ReadTrace needs exactly one of path= or rate= > 0, got "
                f"path={self.path!r} rate={self.rate!r}")

    def arrivals(self, rng: np.random.Generator,
                 horizon: float) -> Iterator[float]:
        """Yield arrival times in ``[0, horizon]``, lazily."""
        if self.path is not None:
            return self._replay(horizon)
        return self._poisson(rng, horizon)

    def _replay(self, horizon: float) -> Iterator[float]:
        with open(self.path) as f:  # buffered: O(1) memory chunked replay
            for line in f:
                line = line.strip()
                if not line:
                    continue
                t = float(json.loads(line)["t"])
                if t > horizon:
                    return
                yield t

    def _poisson(self, rng: np.random.Generator,
                 horizon: float) -> Iterator[float]:
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate)
            if t > horizon:
                return
            yield t


def generate_trace(path: str, rate: float, duration: float, seed: int = 0,
                   chunk: int = 65536) -> int:
    """Write a Poisson arrival trace to ``path``; return the arrival count.

    Gaps are drawn in vectorized chunks and streamed straight to disk,
    so ``rate * duration`` in the millions is fine.  The chunk size does
    not change the output bit-for-bit: draws are sequential, and seeding
    each chunk's accumulate with the running time keeps the float
    recurrence ``t_i = t_{i-1} + gap_i`` identical across any chunking
    (``base + cumsum(chunk)`` would round differently at chunk seams).
    """
    if rate <= 0.0 or duration <= 0.0:
        raise ValueError(f"need rate > 0 and duration > 0, got "
                         f"{rate!r}/{duration!r}")
    rng = np.random.default_rng(seed)
    count, t = 0, 0.0
    with open(path, "w") as f:
        while t <= duration:
            gaps = rng.exponential(1.0 / rate, size=chunk)
            ts = np.add.accumulate(np.concatenate(((t,), gaps)))[1:]
            t = float(ts[-1])
            keep = ts[ts <= duration]
            f.write("".join(f'{{"t": {float(x)!r}}}\n' for x in keep))
            count += int(keep.size)
            if keep.size < ts.size:
                break
    return count


# ---------------------------------------------------------------------------
# In-flight read transfers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(slots=True)
class ReadFlow:
    """A degraded read in flight: ``fanin`` fragment transfers.

    Shares the fluid-progress representation of ``ActiveRepair``
    (``remaining`` fraction of the lockstep schedule, ``nominal``
    solo-time refreshed by ``LinkShareModel.recompute``) so the share
    engine treats reads and repairs as one population of flows.
    """

    rdid: int
    dst: int
    sources: List[int]
    links: List[Tuple[Link, float]]   # [((src, dst), fragment_blocks)]
    arrival: float
    bytes_total: float
    remaining: float = 1.0
    nominal: float = math.inf

    @property
    def node(self) -> int:
        """Check-mode oracle messages name flows by node; use the dst."""
        return self.dst


# ---------------------------------------------------------------------------
# The data plane proper
# ---------------------------------------------------------------------------

class DataPlane:
    """Coded store + read flows + bytes-on-the-wire ledgers for one fleet.

    Owned by ``FleetSimulator`` when ``Scenario(dataplane=True)``; all
    rng here (the store's encode/relay/regenerate draws) lives in
    streams derived from ``seed`` + :data:`_STORE_SALT`, disjoint from
    the fleet's own streams.
    """

    def __init__(self, scenario: "Scenario", params, shares: "LinkShareModel",
                 metrics: "FleetMetrics", seed: int, recorder=None):
        from repro.core import CodeParams  # heavy import kept local
        from repro.storage.simulator import RlncSimulator

        self.scenario = scenario
        self.params = params
        self.shares = shares
        self.metrics = metrics
        self.recorder = recorder
        self.fanin = scenario.read_fanin or params.k
        self.fragment_blocks = float(params.alpha)
        self.block_bytes = float(scenario.dataplane_block_bytes)
        self.verify = scenario.dataplane_verify

        m_c = scenario.dataplane_blocks or 2 * params.k
        if m_c % params.k != 0:
            raise ValueError(
                f"dataplane_blocks={m_c} must be divisible by k={params.k} "
                f"(the mini-code needs integral alpha = M/k)")
        self.mini = CodeParams.msr(n=scenario.num_nodes, k=params.k,
                                   d=params.d, M=float(m_c))
        self.scale = self.mini.alpha / params.alpha
        self.store = RlncSimulator(
            self.mini, block_bytes=scenario.dataplane_payload_bytes,
            seed=(seed * 1_000_003 + _STORE_SALT) % (1 << 31),
            matmul=self._resolve_matmul(scenario.dataplane_matmul))

        self.reads: List[ReadFlow] = []
        self._rd_seq = 0
        self.repair_link_bytes: Dict[Link, float] = {}
        self.read_link_bytes: Dict[Link, float] = {}

    @staticmethod
    def _resolve_matmul(mode: str):
        """GF matmul backend for the coded store.

        ``"numpy"`` uses the field's log/antilog tables; ``"kernel"``
        routes through ``repro.kernels.gf_matmul_numpy`` (Pallas on
        TPU, interpret mode — with a transparent warn-once reference
        fallback — on CPU); ``"auto"`` picks the kernel only when a
        real TPU backend is present, since interpret-mode Pallas is far
        slower than the tables for the store's tiny matmuls.
        """
        if mode == "numpy":
            return None
        from repro.kernels.ops import _on_tpu, gf_matmul_numpy
        if mode == "kernel":
            return gf_matmul_numpy
        return gf_matmul_numpy if _on_tpu() else None

    # -- degraded reads as fragment transfers -------------------------------

    def start_read(self, now: float, dst: int,
                   sources: Sequence[int]) -> ReadFlow:
        fb = self.fragment_blocks
        links = [((int(s), int(dst)), fb) for s in sources]
        fl = ReadFlow(rdid=self._rd_seq, dst=int(dst),
                      sources=[int(s) for s in sources], links=links,
                      arrival=now,
                      bytes_total=len(links) * fb * self.block_bytes)
        self._rd_seq += 1
        self.reads.append(fl)
        self.shares.acquire(links, fl)
        if self.recorder is not None:
            self.recorder.emit(now, "read_queued", rdid=fl.rdid, dst=fl.dst,
                               sources=fl.sources, bytes=fl.bytes_total)
        return fl

    def advance_reads(self, dt: float) -> None:
        """Mirror of the repair progress update in ``FleetSimulator._advance``."""
        if dt == 0.0:
            for fl in self.reads:
                if fl.nominal == 0.0:
                    fl.remaining = 0.0
            return
        for fl in self.reads:
            nom = fl.nominal
            if nom > 0.0 and nom != math.inf:
                rem = fl.remaining - dt / nom
                fl.remaining = rem if rem > 0.0 else 0.0
            elif nom == 0.0:
                fl.remaining = 0.0

    def next_read_completion(self, now: float) -> Tuple[float, int]:
        best_t, best_i = math.inf, -1
        for i, fl in enumerate(self.reads):
            rem = fl.remaining
            t = now + rem * fl.nominal if rem > 0.0 else now
            if t < best_t:
                best_t, best_i = t, i
        return best_t, best_i

    def complete_read(self, i: int, now: float) -> ReadFlow:
        fl = self.reads.pop(i)
        self.shares.release(fl.links, fl)
        for link, f in fl.links:
            self.read_link_bytes[link] = (
                self.read_link_bytes.get(link, 0.0) + f * self.block_bytes)
        self.metrics.on_read_complete(now - fl.arrival, fl.bytes_total)
        if self.recorder is not None:
            self.recorder.emit(now, "read_complete", rdid=fl.rdid,
                               dst=fl.dst, latency=now - fl.arrival,
                               bytes=fl.bytes_total)
        return fl

    def teardown_node(self, node: int, now: float) -> None:
        """A node failed: kill reads it serves or sources.

        Partially transferred fragment bytes did cross the wire and
        stay in the per-link read ledger (and ``read_bytes``); the read
        itself counts as torn down, not completed.
        """
        dead = [i for i, fl in enumerate(self.reads)
                if fl.dst == node or node in fl.sources]
        for i in reversed(dead):
            fl = self.reads.pop(i)
            self.shares.release(fl.links, fl)
            done = 1.0 - fl.remaining
            partial = 0.0
            if done > 0.0:
                for link, f in fl.links:
                    b = done * f * self.block_bytes
                    self.read_link_bytes[link] = (
                        self.read_link_bytes.get(link, 0.0) + b)
                    partial += b
            self.metrics.on_read_teardown(partial)
            if self.recorder is not None:
                self.recorder.emit(now, "read_abort", rdid=fl.rdid,
                                   dst=fl.dst, node=node, bytes=partial)

    # -- repair traffic: wire bytes + coded-block production ----------------

    def account_repair_wire(self, r: "ActiveRepair", done: float) -> None:
        """Bank ``done`` of repair ``r``'s current segment into the ledger.

        Must run *before* the segment's ``shares.release``/``rebase`` —
        those destroy the links/progress the accounting reads.  ``done``
        is the delivered fraction of the lockstep schedule; each link
        carried ``done * residual_flow`` blocks.
        """
        if done <= 0.0:
            return
        bb = self.block_bytes
        total = 0.0
        for link, f in r.links:
            b = done * f * bb
            self.repair_link_bytes[link] = (
                self.repair_link_bytes.get(link, 0.0) + b)
            total += b
        self.metrics.on_repair_bytes(total)

    def _scaled_plan(self, plan):
        """The plan re-expressed in mini-code block units.

        Betas/flows scale exactly by ``alpha_mini / alpha`` (Theorem-1
        constraints are linear, so feasibility is preserved); the ceil
        at execution then only ever adds blocks.
        """
        if self.scale == 1.0:
            return plan
        s = self.scale
        return dataclasses.replace(
            plan, betas=[b * s for b in plan.betas],
            flows={e: f * s for e, f in plan.flows.items()})

    def on_repair_complete(self, r: "ActiveRepair", now: float) -> None:
        """Produce the completed repair's coded blocks on the store."""
        self.store.execute_plan(self._scaled_plan(r.plan), failed=r.node,
                                provider_ids=list(r.ids[1:]))
        if self.recorder is not None:
            for link, f in plan_links(r.plan, r.ids):
                self.recorder.emit(now, "repair_block", rid=r.rid,
                                   producer=link[0], dst=link[1],
                                   bytes=f * self.block_bytes)
        if self.verify:
            self.metrics.on_decode_check(self._decode_check(r.node))

    def _decode_check(self, node: int) -> bool:
        """Can ``k`` nodes including the regenerated one still decode?

        A single k-subset of an MSR-sized RLNC store stacks exactly M
        coding vectors, so any one subset is singular with probability
        ~1/|GF| per draw — the whp caveat the paper's Fig. 10 measures as
        reconstruction *probability*.  Data loss means NO subset decodes,
        so the check slides the (k-1)-window of companion nodes over a few
        positions and fails only when every window does.  Node choice is
        deterministic (sorted other ids), so verification consumes no
        randomness.
        """
        k1 = self.params.k - 1
        others = [i for i in sorted(self.store.nodes) if i != node]
        tries = min(4, max(1, len(others) - k1 + 1))
        for off in range(tries):
            combo = [self.store.nodes[i]
                     for i in [node] + others[off:off + k1]]
            if self.store.rl.can_reconstruct(combo, int(self.mini.M)):
                return True
        return False

    # -- export -------------------------------------------------------------

    @property
    def repair_bytes(self) -> float:
        return sum(self.repair_link_bytes.values())

    @property
    def read_bytes(self) -> float:
        return sum(self.read_link_bytes.values())

    def link_bytes(self) -> Dict[str, Dict[str, float]]:
        """Per-link ``{"src->dst": {"repair_bytes", "read_bytes"}}``."""
        out: Dict[str, Dict[str, float]] = {}
        for link, b in self.repair_link_bytes.items():
            cell = out.setdefault(f"{link[0]}->{link[1]}",
                                  {"repair_bytes": 0.0, "read_bytes": 0.0})
            cell["repair_bytes"] += b
        for link, b in self.read_link_bytes.items():
            cell = out.setdefault(f"{link[0]}->{link[1]}",
                                  {"repair_bytes": 0.0, "read_bytes": 0.0})
            cell["read_bytes"] += b
        return out

    def top_links(self, k: int = 10) -> List[Tuple[str, Dict[str, float]]]:
        """Top-``k`` links by total bytes on the wire (ties by name)."""
        stats = self.link_bytes()
        return sorted(
            stats.items(),
            key=lambda kv: (-(kv[1]["repair_bytes"] + kv[1]["read_bytes"]),
                            kv[0]))[:k]

    def snapshot(self) -> Dict[str, object]:
        """Strict-JSON summary for the flight-recorder header meta."""
        return {
            "block_bytes": self.block_bytes,
            "fragment_blocks": self.fragment_blocks,
            "fanin": self.fanin,
            "mini_blocks": int(self.mini.M),
            "repair_bytes": self.repair_bytes,
            "read_bytes": self.read_bytes,
            "links": self.link_bytes(),
        }
