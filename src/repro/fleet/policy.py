"""Pluggable repair policies: which scheme plans each regeneration.

A policy receives the residual-capacity overlays of *every* repair starting
at the current event epoch as one ``(R, d+1, d+1)`` tensor and returns one
:class:`RepairPlan` per repair.  This batch-shaped interface is what lets
the batched planning engine serve as the decision core: a fixed policy
plans all R repairs with one ``repro.core.plan_many`` call, and the
flexible policy plans all R repairs under *every* candidate scheme (one
batched call per scheme) and picks, per repair, the fastest plan under the
residual capacities — the fleet-scale version of the paper's "choose the
scheme that minimizes regeneration time" message.  Scheme names are
validated against the scheme registry (``repro.core.api``), so a policy
spec for a newly registered scheme works with no fleet-side change.

The residual overlays are a *same-epoch snapshot*: repairs admitted at one
event epoch are planned against the shares left by already-active work,
not against each other (planning them jointly would serialize the batch).
Once they start, the fair-share model charges them for each other anyway,
so a same-epoch batch that collides on a link runs slower than its plans
predicted — the simulator's contention signal, not a planning error.

Custom policies only need ``plan_batch`` (see tests/test_fleet.py for a
crafted-plan policy used to validate the link-sharing model), so anything
from an RL agent to an LP-based global scheduler can plug in.

Since PR 3 the interface has a second batched entry point:
:meth:`RepairPolicy.replan` proposes replacement plans for in-flight
repairs at capacity-shock / provider-loss epochs (plan migration).  The
default delegates to ``plan_batch`` — the flexible policy thereby migrates
a repair to whatever scheme/tree is fastest under the *current* shares,
and a fixed policy re-treeifies within its scheme — while the simulator
applies banked-work credit and keeps the migration only if it wins.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (CodeParams, RepairPlan, get_scheme, plan_many,
                        plans_from_batch, scheme_names)


class RepairPolicy:
    """Interface: plan a batch of repairs under residual capacities."""

    name = "abstract"

    def plan_batch(self, caps: np.ndarray, params: CodeParams,
                   ) -> List[RepairPlan]:
        raise NotImplementedError

    def replan(self, caps: np.ndarray, params: CodeParams,
               ) -> List[Optional[RepairPlan]]:
        """Propose replacement plans for *in-flight* repairs.

        Called by the simulator at capacity-shock and provider-loss epochs
        when ``Scenario.migration`` is on, and — single-row — by the
        watchdog's rescue step when a flagged repair's first mitigation
        attempt replans it in place (``Scenario.watchdog_period`` > 0,
        see ``sim.FleetSimulator._watchdog_replan``).  Either way the
        input is one ``(R, d+1, d+1)`` tensor of *self-excluded* residual
        overlays — each in-flight
        repair's own link occupancy is discounted, so row r is the share
        snapshot that repair would plan under if it released its current
        links.  Return one plan (or ``None`` to decline) per row, same
        batched one-call-per-epoch contract as :meth:`plan_batch`.

        The simulator — not the policy — owns the accept decision: it
        subtracts the repair's banked blocks from the proposal's edge
        demands (credit transfer) and migrates only if the credited ETA
        beats the current one.  The default proposes exactly what
        :meth:`plan_batch` would plan, which gives every policy tree/
        scheme adaptation for free; override to decline or customize.
        """
        return self.plan_batch(caps, params)

    def replan_candidates(self, caps: np.ndarray, params: CodeParams,
                          ) -> List[List[Optional[RepairPlan]]]:
        """All replacement-plan candidates per in-flight repair, for
        bank-aware migration (``Scenario.bank_aware_migration``, ISSUE 8).

        Where :meth:`replan` pre-picks one proposal per repair — by
        nominal time, blind to banked work — this returns the full slate
        so the *simulator* can score each candidate by credited residual
        ETA and prefer trees overlapping already-received blocks.  The
        default slate is the single :meth:`replan` proposal; policies
        with a real scheme race override it.
        """
        return [[p] for p in self.replan(caps, params)]


def _engine_for(scheme: str, engine: str) -> str:
    """Per-scheme engine downgrade for mixed-engine policies.

    A policy-level engine preference (e.g. ``engine="jax"``) must not
    break on schemes that lack that tier — rctree has neither a jax nor a
    batched planner and simply loops the scalar oracle.  The downgrade is
    *declared* by the registry (jax -> batched -> scalar), so it is
    resolved here silently and passed to ``plan_many`` as an exact
    request, instead of letting the dispatcher warn once per scheme about
    a fallback the policy already knows about.
    """
    spec = get_scheme(scheme)
    if engine == "jax" and spec.jax is None:
        engine = "batched"
    if engine == "batched" and spec.batched is None:
        engine = "scalar"
    return engine


class FixedPolicy(RepairPolicy):
    """Always the same scheme (any name in the scheme registry).

    Planning goes through :func:`repro.core.plan_many` with
    ``engine="auto"`` by default: schemes registered with a batched
    planner run it, schemes declared scalar-only (rctree) take the
    per-overlay scalar planner — the registry owns that decision, not
    this class.  ``engine="jax"`` opts the scheme into the jit tier when
    it has one (downgrading silently otherwise, see :func:`_engine_for`).
    """

    def __init__(self, scheme: str, engine: str = "auto"):
        self.spec = get_scheme(scheme)   # raises listing registered schemes
        self.scheme = scheme
        self.name = scheme
        self.engine = engine

    def plan_batch(self, caps: np.ndarray, params: CodeParams,
                   ) -> List[RepairPlan]:
        return plans_from_batch(
            plan_many(caps, params, self.scheme,
                      engine=_engine_for(self.scheme, self.engine)), params)


class FlexiblePolicy(RepairPolicy):
    """Plan every candidate scheme in one batched call each; per repair,
    keep the plan with the smallest regeneration time under the residual
    capacities.  Ties break toward the earlier scheme in ``schemes`` (the
    default order prefers ftr), keeping the choice deterministic.

    Engines are mixed per scheme: the policy-level ``engine`` preference
    is downgraded scheme by scheme (jax -> batched -> scalar, see
    :func:`_engine_for`), so jax-capable schemes go through the jit tier
    in one call each while scalar-only schemes (rctree) loop the scalar
    oracle — a candidate slate may legitimately combine all three
    engines.  The default ``engine="auto"`` reproduces the historical
    batched-with-declared-scalar-fallback behavior bitwise.
    """

    name = "flexible"

    def __init__(self, schemes: Sequence[str] = ("ftr", "tr", "fr", "star"),
                 engine: str = "auto"):
        for s in schemes:
            get_scheme(s)                # raises listing registered schemes
        self.schemes: Tuple[str, ...] = tuple(schemes)
        self.engine = engine

    def _plan_scheme(self, caps: np.ndarray, params: CodeParams,
                     scheme: str) -> List[RepairPlan]:
        return plans_from_batch(
            plan_many(caps, params, scheme,
                      engine=_engine_for(scheme, self.engine)), params)

    def plan_batch(self, caps: np.ndarray, params: CodeParams,
                   ) -> List[RepairPlan]:
        per_scheme = [self._plan_scheme(caps, params, s)
                      for s in self.schemes]
        times = np.array([[p.time for p in plans] for plans in per_scheme])
        winner = np.argmin(times, axis=0)       # first minimum wins ties
        return [per_scheme[int(winner[r])][r] for r in range(caps.shape[0])]

    def replan_candidates(self, caps: np.ndarray, params: CodeParams,
                          ) -> List[List[Optional[RepairPlan]]]:
        """One candidate per scheme per repair, in scheme-preference order
        (so bank-aware scoring ties break toward the earlier scheme,
        matching :meth:`plan_batch`'s determinism)."""
        per_scheme = [self._plan_scheme(caps, params, s)
                      for s in self.schemes]
        return [[plans[r] for plans in per_scheme]
                for r in range(caps.shape[0])]


def make_policy(spec: str, engine: str = "auto") -> RepairPolicy:
    """'flexible' or a fixed scheme name — the CLI/bench entry point.

    ``engine`` is the policy-level preference ("auto" | "scalar" |
    "batched" | "jax"), downgraded per scheme by :func:`_engine_for`.
    """
    if spec == "flexible":
        return FlexiblePolicy(engine=engine)
    return FixedPolicy(spec, engine=engine)
