"""Lockstep multi-cluster Monte-Carlo: region scale from cluster sims.

A region is not one giant cluster — it is many independent clusters run
under the same operational regime.  This module drives K
:class:`~repro.fleet.sim.FleetSimulator` instances (one scenario, K
distinct seed streams) in *lockstep*: a single event-time heap pops
whichever cluster owns the globally next event and advances exactly that
one by one event.  Each cluster's trajectory is untouched by the
interleaving — cluster state is fully private, so every member produces
bit-for-bit the metrics its solo ``run()`` would (pinned by
tests/test_ensemble.py) — but the single-driver structure is what a
region-scale study needs: one wall clock, one place to observe the whole
fleet mid-flight, and the hook point for any future cross-cluster
coupling (shared WAN budget, global repair throttles).

Statistics come out two ways:

* :func:`pool_metrics` — one pooled :class:`FleetMetrics` whose
  ``summary()`` is the region-level estimate: time-integrals, counters
  and sim-time sum across clusters (so ``mean_backlog`` is the
  cluster-time-weighted mean |Σ∫b dt / Σdur| and ``mttdl_estimate`` is
  ``Σdur / ΣE[losses]``), per-repair samples concatenate (so pooled
  percentiles weight clusters by how many repairs they actually ran).
* :func:`bootstrap_cis` — cluster-level bootstrap: resample the K
  member metrics with replacement, re-pool, re-summarize.  Clusters are
  the i.i.d. unit here (repairs within one cluster are autocorrelated
  through its queue), so resampling clusters is the defensible CI, and
  it needs no distributional assumption on heavy-tailed keys like
  ``regen_p99``.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import CodeParams

from .metrics import COUNTER_SUMMARY_KEYS, FleetMetrics
from .policy import RepairPolicy
from .scenario import Scenario
from .sim import FleetSimulator

__all__ = ["ClusterEnsemble", "bootstrap_cis", "cluster_seed",
           "pool_metrics"]


def cluster_seed(root_seed: int, k: int) -> int:
    """Derived seed for ensemble member ``k`` — distinct, deterministic,
    and stable under changing K (member 3 keeps its trajectory whether
    the ensemble has 4 or 400 clusters)."""
    return (root_seed * 1_000_003 + k) % (1 << 31)


def pool_metrics(members: Sequence[FleetMetrics]) -> FleetMetrics:
    """Pool member metrics into one region-level :class:`FleetMetrics`.

    Time integrals (``backlog_integral``, ``unavail_time``,
    ``at_risk_time``, ``expected_losses``) and ``now`` sum, so every
    ratio ``summary()`` forms over duration is automatically the
    cluster-time-weighted pooled estimate.  Counters sum via the
    :data:`COUNTER_SUMMARY_KEYS` registry (anything added there pools
    with no change here), except ``max_backlog`` which pools as a max —
    it is a high-water mark, not a flow.  Sample lists concatenate.
    The pooled object is an accumulator snapshot: call ``summary()`` on
    it, don't ``observe()`` into it.
    """
    if not members:
        raise ValueError("cannot pool an empty ensemble")
    base = members[0]
    pooled = FleetMetrics(n=base.n, k=base.k, failure_rate=base.failure_rate)
    for m in members:
        pooled.now += m.now
        pooled.backlog_integral += m.backlog_integral
        pooled.unavail_time += m.unavail_time
        pooled.at_risk_time += m.at_risk_time
        pooled.expected_losses += m.expected_losses
        for attr in COUNTER_SUMMARY_KEYS:
            if attr == "max_backlog":
                pooled.max_backlog = max(pooled.max_backlog, m.max_backlog)
            else:
                setattr(pooled, attr,
                        getattr(pooled, attr) + getattr(m, attr))
        pooled.plan_errors.extend(m.plan_errors)
        pooled.credit_fractions.extend(m.credit_fractions)
        pooled.regen_times.extend(m.regen_times)
        pooled.vulnerability_windows.extend(m.vulnerability_windows)
        pooled.wait_times.extend(m.wait_times)
        pooled.read_latencies.extend(m.read_latencies)
        # dataplane summary keys are conditional on the flag, so a single
        # dataplane member is enough to surface them for the whole pool
        pooled.dataplane = pooled.dataplane or m.dataplane
    return pooled


def bootstrap_cis(members: Sequence[FleetMetrics], keys: Sequence[str],
                  n_boot: int = 200, alpha: float = 0.05,
                  seed: int = 0) -> Dict[str, Tuple[float, float, float]]:
    """Cluster-level bootstrap CIs for pooled summary keys.

    Returns ``{key: (lo, point, hi)}`` where ``point`` is the pooled
    estimate over the real ensemble and ``(lo, hi)`` are the
    ``alpha/2`` / ``1 - alpha/2`` percentiles of ``n_boot`` re-pooled
    resamples (clusters drawn with replacement).  Deterministic in
    ``seed``; an ensemble of identical members yields zero-width
    intervals (every resample is the same multiset — pinned by
    tests/test_ensemble.py).
    """
    if not members:
        raise ValueError("cannot bootstrap an empty ensemble")
    point = pool_metrics(members).summary()
    rng = np.random.default_rng([seed, 0xB007])
    kk = len(members)
    draws: Dict[str, List[float]] = {key: [] for key in keys}
    for _ in range(n_boot):
        idx = rng.integers(0, kk, size=kk)
        s = pool_metrics([members[int(i)] for i in idx]).summary()
        for key in keys:
            draws[key].append(float(s[key]))
    out: Dict[str, Tuple[float, float, float]] = {}
    lo_q, hi_q = 100.0 * (alpha / 2.0), 100.0 * (1.0 - alpha / 2.0)
    for key in keys:
        xs = np.asarray(draws[key], dtype=np.float64)
        if np.isfinite(xs).all():
            lo, hi = (float(np.percentile(xs, lo_q)),
                      float(np.percentile(xs, hi_q)))
        else:                       # e.g. mttdl with zero expected losses
            lo, hi = float(np.min(xs)), float(np.max(xs))
        out[key] = (lo, float(point[key]), hi)
    return out


class ClusterEnsemble:
    """K clusters, one scenario, one lockstep event driver.

    ``policy_factory`` is called once per member so stateful policies
    never share state across clusters (the built-in policies are
    stateless, but the contract should not depend on that).
    """

    def __init__(self, scenario: Scenario,
                 policy_factory: Callable[[], RepairPolicy],
                 params: CodeParams, clusters: int,
                 root_seed: int = 0, check_shares: bool = False):
        if clusters < 1:
            raise ValueError("ensemble needs at least one cluster")
        self.scenario = scenario
        self.seeds = [cluster_seed(root_seed, k) for k in range(clusters)]
        self.sims: List[FleetSimulator] = [
            FleetSimulator(scenario, policy_factory(), params, seed=s,
                           check_shares=check_shares)
            for s in self.seeds]
        self.members: Optional[List[FleetMetrics]] = None

    def run(self) -> List[FleetMetrics]:
        """Advance all clusters to the horizon, globally next event first.

        The heap holds ``(next_event_time, member_index)``; ties break
        toward the lower member index (heap tuple order), so the drive
        order is deterministic.  A member whose ``step()`` returns False
        has crossed the horizon and leaves the heap.
        """
        sims = self.sims
        for sim in sims:
            sim.start()
        heap = [(sim.next_event_time(), i) for i, sim in enumerate(sims)]
        heapq.heapify(heap)
        while heap:
            _, i = heapq.heappop(heap)
            sim = sims[i]
            if sim.step():
                heapq.heappush(heap, (sim.next_event_time(), i))
        self.members = [sim.finish() for sim in sims]
        return self.members

    # -- region-level statistics -------------------------------------------

    def pooled(self) -> FleetMetrics:
        if self.members is None:
            self.run()
        return pool_metrics(self.members)

    def cis(self, keys: Sequence[str], n_boot: int = 200,
            alpha: float = 0.05, seed: int = 0,
            ) -> Dict[str, Tuple[float, float, float]]:
        if self.members is None:
            self.run()
        return bootstrap_cis(self.members, keys, n_boot=n_boot,
                             alpha=alpha, seed=seed)
