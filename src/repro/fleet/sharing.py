"""Fair-share model for directed links used by concurrent regenerations.

Every active repair (and every phantom degraded-read stream) *occupies* the
directed physical links its plan sends data over.  A link of capacity ``c``
with ``m`` occupants gives each of them the fair share ``c / m`` — the fluid
approximation of per-flow max-min fairness on independent links.  Repair
progress is store-and-forward over the plan tree, so a repair's *nominal
duration* under the current shares is

    T = max over plan edges e of  f_e / share(link(e))

exactly the paper's regeneration-time expression with capacities replaced
by shares.  Between events a repair advances at rate ``1 / T`` of its total
work; the simulator integrates the remaining-work fraction piecewise.

Consequences the tests pin down (tests/test_fleet.py):

* a lone repair sees full capacities — its fleet time equals ``plan.time``;
* repairs over disjoint links do not affect each other at all;
* two plans bottlenecked on one shared saturated link each see ``c / 2``
  and slow down by exactly 2x while they overlap.

All divisions are guarded: a zero-capacity link yields an ``inf`` nominal
duration (the repair stalls, matching ``plan_time``'s convention), never a
ZeroDivisionError; flows below ``FLOW_EPS`` occupy nothing.

Progress is a *vector*, not a scalar (PR 3): each repair tracks blocks
received per physical link (``ActiveRepair.bank`` + the in-flight lockstep
fraction).  On a provider-loss abort the banked blocks survive with the
queued slot, and on re-admission or in-flight migration
:func:`apply_credit` subtracts them from the new plan's edge demands —
only the missing flows are re-transferred.  With carryover and migration
disabled the bank stays empty and every arithmetic step reduces bitwise to
the scalar-\\ ``remaining`` model this replaces.

Plan vs reality (ISSUE 6): the model distinguishes a *believed* view (what
policies plan against and ETAs are predicted from) from the *true* view
(what flows actually achieve).  ``believed`` is an optional separate
capacity matrix the simulator refreshes on its estimate schedule —
:meth:`residual_overlay`, :meth:`residual` and :meth:`admission_time` read
it; ``out_mult`` is an optional per-source-node multiplier vector modelling
silent link brownouts (stragglers/stalls) — :meth:`share` and
:meth:`nominal_time` apply it, so actual progress slows while the believed
view stays oblivious.  Both default to off (``believed=None`` aliases the
true matrix, ``out_mult=None`` skips the multiply), which keeps the default
path bitwise identical to the pre-robustness model.

Incremental sharing engine (ISSUE 8): :meth:`recompute` no longer rescans
every active repair on every event.  The model keeps a link -> repairs
index (populated by passing ``repair=`` to :meth:`acquire` /
:meth:`release`) and a set of *touched* links — links whose user count
changed since the last recompute, plus links invalidated by capacity
changes (:meth:`invalidate_all` for in-place matrix rescales,
:meth:`invalidate_source` for per-node brownout multiplier flips).  A
recompute then refreshes only the repairs occupying a touched link.  This
is bitwise identical to the full rescan because a repair's nominal
duration is a pure function of (its residual links, the true capacities,
the per-link user counts): if none of those inputs changed, recomputing
would reproduce the exact same float.  The full rescan survives two ways:
as the automatic fallback whenever the index cannot be trusted (callers
that never register repairs, e.g. the closed-form tests), and as a debug
oracle behind ``LinkShareModel(caps, check=True)``, which re-derives every
nominal from scratch after each incremental update and asserts bitwise
equality (tests/test_sharing_incremental.py drives random
arrival/departure/brownout/shock sequences through it).

The occupancy ledger is also mirrored into a dense ``users_mat`` array so
:meth:`residual_overlay` / :meth:`residual_overlays` are single gather +
divide array programs over repairs x links instead of per-entry Python
loops (``x / 1.0`` is IEEE-exact, so dividing untouched entries by one is
bitwise identical to not dividing them).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import RepairPlan

Link = Tuple[int, int]          # directed physical link (src node, dst node)

FLOW_EPS = 1e-12                # flows at/below this occupy no link


def plan_links(plan: RepairPlan, ids: Sequence[int],
               ) -> List[Tuple[Link, float]]:
    """Map a plan's tree edges onto physical links.

    ``ids[i]`` is the cluster node standing at overlay index ``i`` (index 0
    = the replacement/newcomer).  Edges with negligible flow are dropped —
    they move no data and must not claim a share.
    """
    out: List[Tuple[Link, float]] = []
    for (u, v), f in plan.flows.items():
        if f > FLOW_EPS:
            out.append(((ids[u], ids[v]), float(f)))
    return out


def apply_credit(flows: Sequence[Tuple[Link, float]],
                 bank: Dict[Link, float],
                 ) -> Tuple[List[Tuple[Link, float]], float, float]:
    """Subtract banked blocks from a plan's per-link demands.

    Returns ``(residual links, credited blocks, total planned blocks)``.
    Credit on each link is capped at the plan's demand there; links whose
    demand is fully prepaid drop out (they move no further data and must
    not claim a share).  Bank entries on links the plan does not use are
    left untouched in ``bank`` — they stay available for a later
    migration back onto those links.
    """
    out: List[Tuple[Link, float]] = []
    credited = 0.0
    total = 0.0
    for link, f in flows:
        total += f
        credit = min(bank.get(link, 0.0), f)
        credited += credit
        resid = f - credit
        if resid > FLOW_EPS:
            out.append((link, resid))
    return out, credited, total


@dataclasses.dataclass(slots=True)
class ActiveRepair:
    """A regeneration in flight, with per-plan-edge progress state.

    ``links`` holds the *residual* demand per physical link fixed at the
    last (re)plan: the plan's per-edge flows minus any banked credit.
    ``remaining`` is the fraction of that residual work left (1 at a fresh
    (re)plan); ``nominal`` is the duration the residual work would take at
    the *current* shares.  Time to finish right now is
    ``remaining * nominal``.

    Progress is fluid store-and-forward: every residual edge advances in
    lockstep fraction ``1 - remaining``, so a child edge has always
    delivered the same fraction of its demand as its parent — no node ever
    forwards blocks it has not received.  ``bank`` records blocks received
    *before* the last (re)plan (per physical link, across the repair's
    whole life); :meth:`banked_now` folds the in-flight fraction on top.

    The progress-vector invariant (pinned by tests/test_fleet.py): for
    every edge of the current plan,

        banked_now(e) + remaining * residual(e) == plan flow on e

    i.e. banked plus outstanding work always equals the plan total —
    credit transfer never creates or destroys work.
    """

    node: int                           # slot being regenerated
    plan: RepairPlan
    ids: List[int]                      # overlay index -> cluster node
    links: List[Tuple[Link, float]]     # physical link -> residual demand
    fail_time: float
    start_time: float
    bank: Dict[Link, float] = dataclasses.field(default_factory=dict)
    remaining: float = 1.0
    nominal: float = math.inf
    # -- plan-vs-reality bookkeeping (ISSUE 6; inert unless the watchdog /
    #    estimate machinery is on, except the plan-error observation) ------
    plan_t0: float = 0.0                # time of the last (re)plan
    predicted: float = math.inf         # ETA predicted at the last (re)plan
    #                                     under the *believed* capacities
    retries: int = 0                    # watchdog mitigation attempts so far
    next_check: float = 0.0             # watchdog skips this repair until
    #                                     then (exponential backoff)
    avoid: Tuple[int, ...] = ()         # providers evicted as stragglers —
    #                                     not re-drawn while alternatives
    #                                     exist
    rid: int = -1                       # repair id for the flight recorder
    #                                     (ISSUE 7): stable across aborts,
    #                                     evictions and re-admissions, so a
    #                                     slot's whole lifecycle shares one
    #                                     span tree.  -1 when tracing is off

    @property
    def providers(self) -> List[int]:
        return list(self.ids[1:])

    def eta(self) -> float:
        if self.remaining <= 0.0:
            return 0.0
        return self.remaining * self.nominal

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"negative time step {dt}")
        if math.isfinite(self.nominal) and self.nominal > 0:
            self.remaining = max(0.0, self.remaining - dt / self.nominal)
        elif self.nominal == 0.0:       # degenerate all-tiny-flow plan
            self.remaining = 0.0

    def banked_now(self) -> Dict[Link, float]:
        """Blocks received per physical link as of right now: the bank
        fixed at the last (re)plan plus the in-flight lockstep fraction of
        every residual edge."""
        out = dict(self.bank)
        done = 1.0 - self.remaining
        if done > 0.0:
            for link, resid in self.links:
                out[link] = out.get(link, 0.0) + done * resid
        return out

    def rebase(self, plan: RepairPlan,
               links: List[Tuple[Link, float]],
               bank: Dict[Link, float]) -> None:
        """Migrate onto ``plan``: residual ``links`` (post-credit) become
        the new work vector and progress restarts at fraction 1 — the
        banked work lives on in ``bank``."""
        self.plan = plan
        self.links = links
        self.bank = bank
        self.remaining = 1.0
        self.nominal = math.inf

    def work_accounting(self,
                        ) -> Dict[Link, Tuple[float, float, float]]:
        """Per current-plan link: (banked, outstanding, plan total) — the
        conservation triple the progress-vector invariant constrains.
        Banked counts only blocks attributable to this plan's edge (credit
        at the last (re)plan plus the in-flight lockstep fraction), so
        banked + outstanding == plan total identically."""
        resid0 = dict(self.links)
        done = 1.0 - self.remaining
        out = {}
        for link, f in plan_links(self.plan, self.ids):
            r0 = resid0.get(link, 0.0)
            credit = f - r0         # blocks credited at the last (re)plan
            out[link] = (credit + done * r0, self.remaining * r0, f)
        return out


class LinkShareModel:
    """Occupancy ledger over the cluster's directed capacity matrix.

    Holds a *reference* to ``caps`` so capacity shocks (the simulator
    rescales the matrix in place) are seen by the next ``recompute``.

    ``believed`` (optional) is the planner-side view of the matrix: when
    set, predictions (:meth:`residual`, :meth:`residual_overlay`,
    :meth:`admission_time`) read it while actual rates (:meth:`share`,
    :meth:`nominal_time`) keep reading ``caps``.  ``out_mult`` (optional)
    is a per-source-node rate multiplier for silent brownouts: it scales
    the *true* rates only — a degraded node looks fine to the planner.

    ``tracer`` (optional, ISSUE 7) observes the occupancy ledger: every
    per-link user-count change in :meth:`acquire` / :meth:`release` is
    reported to ``tracer.on_users(link, users)`` (the
    ``repro.obs.timeline.LinkUsageTracer`` contract), from which exact
    utilization/contention timelines are integrated online.  ``None``
    (default) skips the calls — the share arithmetic itself is never
    touched, so tracing cannot perturb a run.

    Incremental recompute (ISSUE 8): callers that pass ``repair=`` to
    :meth:`acquire` / :meth:`release` opt into delta recomputes — only
    repairs occupying a link whose user count (or effective capacity, via
    :meth:`invalidate_all` / :meth:`invalidate_source`) changed since the
    last :meth:`recompute` get their nominal refreshed.  Callers that
    never register fall back to the full rescan automatically.
    ``check=True`` keeps the incremental path but re-derives every nominal
    from scratch after each recompute and asserts bitwise equality — the
    debug oracle the property tests drive.
    """

    def __init__(self, caps: np.ndarray,
                 believed: Optional[np.ndarray] = None,
                 check: bool = False):
        self.caps = caps
        self.believed = believed
        self.out_mult: Optional[np.ndarray] = None
        self.tracer = None
        self.check = check
        self.users: Dict[Link, int] = {}
        # dense mirror of ``users`` for the vectorized overlay gathers;
        # int64 keeps ``m + 1.0`` exact for any realistic user count
        self.users_mat = np.zeros(caps.shape, dtype=np.int64)
        # -- incremental-recompute index (ISSUE 8) --------------------------
        self._by_link: Dict[Link, Dict[int, ActiveRepair]] = {}
        self._reg: Dict[int, ActiveRepair] = {}         # all registered
        self._unlinked: Dict[int, ActiveRepair] = {}    # registered, no
        #                                                 residual links
        self._touched: set = set()      # links whose users/capacity changed
        self._all_touched = True        # capacities unseen yet: full scan

    def true_cap(self, link: Link) -> float:
        """Actual capacity of ``link`` right now (brownouts applied)."""
        c = float(self.caps[link])
        if self.out_mult is not None:
            c *= float(self.out_mult[link[0]])
        return c

    def believed_cap(self, link: Link) -> float:
        """Capacity of ``link`` according to the planner's current view."""
        mat = self.caps if self.believed is None else self.believed
        return float(mat[link])

    def acquire(self, links: Sequence[Tuple[Link, float]],
                repair: Optional[ActiveRepair] = None) -> None:
        """Claim one occupancy unit per link.  Passing the owning
        ``repair`` registers it in the link -> repairs index so the next
        :meth:`recompute` can refresh only affected repairs; anonymous
        flows (degraded reads) still mark their links touched."""
        users = self.users
        mat = self.users_mat
        touched = self._touched
        tracer = self.tracer
        for link, _ in links:
            m = users.get(link, 0) + 1
            users[link] = m
            mat[link] = m
            touched.add(link)
            if tracer is not None:
                tracer.on_users(link, m)
        if repair is not None:
            key = id(repair)
            self._reg[key] = repair
            if links:
                for link, _ in links:
                    self._by_link.setdefault(link, {})[key] = repair
            else:
                # a fully-prepaid plan occupies nothing but still needs its
                # (zero) nominal set by the next recompute
                self._unlinked[key] = repair

    def release(self, links: Sequence[Tuple[Link, float]],
                repair: Optional[ActiveRepair] = None) -> None:
        users = self.users
        mat = self.users_mat
        touched = self._touched
        tracer = self.tracer
        for link, _ in links:
            m = users.get(link, 0) - 1
            if m > 0:
                users[link] = m
                mat[link] = m
            else:
                users.pop(link, None)
                mat[link] = 0
            touched.add(link)
            if tracer is not None:
                tracer.on_users(link, max(m, 0))
        if repair is not None:
            key = id(repair)
            self._reg.pop(key, None)
            self._unlinked.pop(key, None)
            for link, _ in links:
                d = self._by_link.get(link)
                if d is not None:
                    d.pop(key, None)
                    if not d:
                        del self._by_link[link]

    # -- capacity-change invalidation (ISSUE 8) -----------------------------

    def invalidate_all(self) -> None:
        """Every effective capacity may have changed (the simulator
        rescaled ``caps`` in place): the next :meth:`recompute` falls back
        to the full rescan."""
        self._all_touched = True

    def invalidate_source(self, node: int) -> None:
        """``node``'s outgoing effective rates changed (brownout applied
        or lifted): mark its occupied outgoing links touched so their
        repairs get re-shared at the next :meth:`recompute`."""
        touched = self._touched
        for link in self._by_link:
            if link[0] == node:
                touched.add(link)

    def share(self, link: Link) -> float:
        """Bandwidth each current occupant of ``link`` receives."""
        c = self.true_cap(link)
        m = max(self.users.get(link, 0), 1)
        return c / m

    def residual(self, link: Link) -> float:
        """Bandwidth a *new* occupant of ``link`` would get, as believed."""
        c = self.believed_cap(link)
        return c / (self.users.get(link, 0) + 1)

    def residual_overlay(self, ids: Sequence[int],
                         exclude: frozenset = frozenset()) -> np.ndarray:
        """(d+1, d+1) overlay capacity matrix for planning a new repair.

        Entry [i, j] is the fair share a new flow on physical link
        (ids[i], ids[j]) would get — the "current residual capacity" the
        flexible policy plans under.  ``exclude`` discounts one existing
        claim on each named link: when an *in-flight* repair evaluates its
        own migration, its current occupancy must not be charged against
        the plans that would replace it.

        Reads the *believed* matrix when one is set — this is the
        planner's map, not the territory (``sim.py`` keeps them apart when
        estimate error is injected).

        One gather + one divide over the dense ``users_mat`` mirror
        (entries with no users divide by exactly 1.0, which is IEEE-exact,
        so the result is bitwise identical to the per-entry loop this
        replaced).
        """
        idx = np.asarray(ids)
        mat = self.caps if self.believed is None else self.believed
        cap = mat[np.ix_(idx, idx)].copy()
        m = self.users_mat[np.ix_(idx, idx)].astype(np.float64)
        if exclude:
            pos = {int(u): i for i, u in enumerate(idx)}
            for (u, v) in exclude:
                i = pos.get(u)
                j = pos.get(v)
                if i is not None and j is not None and m[i, j] > 0:
                    m[i, j] -= 1.0
        cap /= np.where(m > 0, m + 1.0, 1.0)
        np.fill_diagonal(cap, 0.0)
        return cap

    def residual_overlays(self, ids_list: Sequence[Sequence[int]],
                          excludes: Optional[Sequence[frozenset]] = None,
                          ) -> np.ndarray:
        """Stacked ``(R, d+1, d+1)`` residual overlays, one row per
        candidate repair — the batched form of :meth:`residual_overlay`
        the simulator feeds to ``policy.plan_batch`` / ``policy.replan``.
        All id tuples must share one fan-out (the simulator groups
        admissions and replans by d); ``excludes[r]``, when given,
        discounts repair r's own claims exactly like the scalar method.
        Bitwise identical to stacking R scalar calls."""
        idx = np.asarray(ids_list)
        mat = self.caps if self.believed is None else self.believed
        rows = idx[:, :, None]
        cols = idx[:, None, :]
        cap = mat[rows, cols].astype(np.float64, copy=True)
        m = self.users_mat[rows, cols].astype(np.float64)
        if excludes is not None:
            for r, excl in enumerate(excludes):
                if not excl:
                    continue
                pos = {int(u): i for i, u in enumerate(idx[r])}
                for (u, v) in excl:
                    i = pos.get(u)
                    j = pos.get(v)
                    if i is not None and j is not None and m[r, i, j] > 0:
                        m[r, i, j] -= 1.0
        cap /= np.where(m > 0, m + 1.0, 1.0)
        w = cap.shape[1]
        cap[:, np.arange(w), np.arange(w)] = 0.0
        return cap

    def admission_time(self, links: Sequence[Tuple[Link, float]],
                       exclude: frozenset = frozenset()) -> float:
        """Store-and-forward duration the given residual demands would see
        if admitted *now* (each link charged as one new occupant).  With
        ``exclude`` = an in-flight repair's current links, this is the
        migrated-plan ETA the simulator compares against ``eta()``.  A
        *prediction*, so it reads the believed matrix when one is set."""
        mat = self.caps if self.believed is None else self.believed
        users = self.users
        t = 0.0
        for link, f in links:
            if f <= FLOW_EPS:
                continue
            c = float(mat[link])
            m = users.get(link, 0)
            if link in exclude and m:
                m -= 1
            s = c / (m + 1)
            if s <= 0.0:
                return math.inf
            tl = f / s
            if tl > t:
                t = tl
        return t

    def nominal_time(self, links: Sequence[Tuple[Link, float]]) -> float:
        """Store-and-forward duration of a plan at the current shares.

        Same arithmetic as ``max(f / self.share(link))`` with the
        attribute lookups hoisted — this is the recompute hot loop."""
        caps = self.caps
        om = self.out_mult
        users = self.users
        t = 0.0
        for link, f in links:
            if f <= FLOW_EPS:
                continue
            c = float(caps[link])
            if om is not None:
                c *= float(om[link[0]])
            m = users.get(link, 0)
            if m > 1:
                s = c / m
            else:
                s = c
            if s <= 0.0:
                return math.inf
            tl = f / s
            if tl > t:
                t = tl
        return t

    def recompute(self, active: Sequence[ActiveRepair]) -> None:
        """Refresh active repairs' nominal durations (call after any
        arrival, departure, or capacity change).

        When every repair in ``active`` is registered (the simulator's
        path), only repairs occupying a *touched* link are refreshed — a
        repair none of whose links changed users or capacity would
        recompute to the bit-identical float, so skipping it is exact.
        Unregistered callers (or a global invalidation) get the full
        rescan.  With ``check=True`` a full rescan shadows every
        incremental result and asserts bitwise equality."""
        if self._all_touched or len(self._reg) != len(active):
            for r in active:
                r.nominal = self.nominal_time(r.links)
            self._all_touched = False
            self._touched.clear()
            return
        touched = self._touched
        if touched:
            by_link = self._by_link
            seen: set = set()
            nominal_time = self.nominal_time
            for link in touched:
                d = by_link.get(link)
                if d:
                    for key, r in d.items():
                        if key not in seen:
                            seen.add(key)
                            r.nominal = nominal_time(r.links)
            touched.clear()
        for r in self._unlinked.values():
            r.nominal = self.nominal_time(r.links)      # == 0.0 always
        if self.check:
            for r in active:
                want = self.nominal_time(r.links)
                assert r.nominal == want, (
                    f"incremental recompute diverged for repair of slot "
                    f"{r.node}: incremental={r.nominal!r} full={want!r}")
