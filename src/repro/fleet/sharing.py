"""Fair-share model for directed links used by concurrent regenerations.

Every active repair (and every phantom degraded-read stream) *occupies* the
directed physical links its plan sends data over.  A link of capacity ``c``
with ``m`` occupants gives each of them the fair share ``c / m`` — the fluid
approximation of per-flow max-min fairness on independent links.  Repair
progress is store-and-forward over the plan tree, so a repair's *nominal
duration* under the current shares is

    T = max over plan edges e of  f_e / share(link(e))

exactly the paper's regeneration-time expression with capacities replaced
by shares.  Between events a repair advances at rate ``1 / T`` of its total
work; the simulator integrates the remaining-work fraction piecewise.

Consequences the tests pin down (tests/test_fleet.py):

* a lone repair sees full capacities — its fleet time equals ``plan.time``;
* repairs over disjoint links do not affect each other at all;
* two plans bottlenecked on one shared saturated link each see ``c / 2``
  and slow down by exactly 2x while they overlap.

All divisions are guarded: a zero-capacity link yields an ``inf`` nominal
duration (the repair stalls, matching ``plan_time``'s convention), never a
ZeroDivisionError; flows below ``FLOW_EPS`` occupy nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import RepairPlan

Link = Tuple[int, int]          # directed physical link (src node, dst node)

FLOW_EPS = 1e-12                # flows at/below this occupy no link


def plan_links(plan: RepairPlan, ids: Sequence[int],
               ) -> List[Tuple[Link, float]]:
    """Map a plan's tree edges onto physical links.

    ``ids[i]`` is the cluster node standing at overlay index ``i`` (index 0
    = the replacement/newcomer).  Edges with negligible flow are dropped —
    they move no data and must not claim a share.
    """
    out: List[Tuple[Link, float]] = []
    for (u, v), f in plan.flows.items():
        if f > FLOW_EPS:
            out.append(((ids[u], ids[v]), float(f)))
    return out


@dataclasses.dataclass
class ActiveRepair:
    """A regeneration in flight.

    ``remaining`` is the fraction of total work left (1 at start);
    ``nominal`` is the duration the whole repair would take at the *current*
    shares.  Time to finish right now is ``remaining * nominal``.
    """

    node: int                           # slot being regenerated
    plan: RepairPlan
    ids: List[int]                      # overlay index -> cluster node
    links: List[Tuple[Link, float]]     # physical link -> flow on it
    fail_time: float
    start_time: float
    remaining: float = 1.0
    nominal: float = math.inf

    @property
    def providers(self) -> List[int]:
        return list(self.ids[1:])

    def eta(self) -> float:
        if self.remaining <= 0.0:
            return 0.0
        return self.remaining * self.nominal

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"negative time step {dt}")
        if math.isfinite(self.nominal) and self.nominal > 0:
            self.remaining = max(0.0, self.remaining - dt / self.nominal)
        elif self.nominal == 0.0:       # degenerate all-tiny-flow plan
            self.remaining = 0.0


class LinkShareModel:
    """Occupancy ledger over the cluster's directed capacity matrix.

    Holds a *reference* to ``caps`` so capacity shocks (the simulator
    rescales the matrix in place) are seen by the next ``recompute``.
    """

    def __init__(self, caps: np.ndarray):
        self.caps = caps
        self.users: Dict[Link, int] = {}

    def acquire(self, links: Sequence[Tuple[Link, float]]) -> None:
        for link, _ in links:
            self.users[link] = self.users.get(link, 0) + 1

    def release(self, links: Sequence[Tuple[Link, float]]) -> None:
        for link, _ in links:
            m = self.users.get(link, 0) - 1
            if m > 0:
                self.users[link] = m
            else:
                self.users.pop(link, None)

    def share(self, link: Link) -> float:
        """Bandwidth each current occupant of ``link`` receives."""
        c = float(self.caps[link])
        m = max(self.users.get(link, 0), 1)
        return c / m

    def residual(self, link: Link) -> float:
        """Bandwidth a *new* occupant of ``link`` would receive."""
        c = float(self.caps[link])
        return c / (self.users.get(link, 0) + 1)

    def residual_overlay(self, ids: Sequence[int]) -> np.ndarray:
        """(d+1, d+1) overlay capacity matrix for planning a new repair.

        Entry [i, j] is the fair share a new flow on physical link
        (ids[i], ids[j]) would get — the "current residual capacity" the
        flexible policy plans under.
        """
        idx = np.asarray(ids)
        cap = self.caps[np.ix_(idx, idx)].copy()
        np.fill_diagonal(cap, 0.0)
        for i, u in enumerate(idx):
            for j, v in enumerate(idx):
                if i != j:
                    m = self.users.get((int(u), int(v)), 0)
                    if m:
                        cap[i, j] /= (m + 1)
        return cap

    def nominal_time(self, links: Sequence[Tuple[Link, float]]) -> float:
        """Store-and-forward duration of a plan at the current shares."""
        t = 0.0
        for link, f in links:
            if f <= FLOW_EPS:
                continue
            s = self.share(link)
            if s <= 0.0:
                return math.inf
            t = max(t, f / s)
        return t

    def recompute(self, active: Sequence[ActiveRepair]) -> None:
        """Refresh every active repair's nominal duration (call after any
        arrival, departure, or capacity change)."""
        for r in active:
            r.nominal = self.nominal_time(r.links)
