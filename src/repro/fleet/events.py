"""Event primitives for the fleet simulator.

The simulator distinguishes *exogenous* events — scheduled ahead of time on
a heap (failures, capacity shocks, degraded-read arrivals/departures, end of
horizon) — from repair *completions*, which are never enqueued: a repair's
finish time moves every time link shares change, so completions are derived
fresh each iteration from (remaining work, current nominal duration).  This
sidesteps the classic stale-heap-entry problem of processor-sharing
simulations entirely.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Tuple

# Event kinds (exogenous only — completions are derived, see module doc).
FAILURE = "failure"
CAPACITY_SHOCK = "capacity_shock"
READ_ARRIVAL = "read_arrival"
READ_DEPARTURE = "read_departure"

# Robustness family (ISSUE 6).  DEGRADE multiplies a live node's *outgoing*
# link rates by a factor in [0, 1) without failing the host — factor 0.0 is
# a full stall, the fault class the provider-loss abort path cannot see;
# RECOVER restores the node (payload carries a generation counter so a
# re-degrade supersedes a stale recovery).  ESTIMATE_REFRESH re-snapshots
# the planner's believed capacity matrix; WATCHDOG is the periodic progress
# check that drives retry/backoff mitigation.
DEGRADE = "degrade"
RECOVER = "recover"
ESTIMATE_REFRESH = "estimate_refresh"
WATCHDOG = "watchdog"

# Coded data plane (ISSUE 10): an open-loop read arrival replayed from a
# ``ReadTrace`` — unlike READ_ARRIVAL these fire on the trace's own clock
# whether or not a slot is down (payload: none; the next trace line is
# pulled lazily when this one fires).
TRACE_READ = "trace_read"


@dataclasses.dataclass(frozen=True)
class Event:
    """A scheduled exogenous event.

    ``payload`` is kind-specific: the victim node for an injected FAILURE
    (or None for a Poisson draw resolved at fire time), the read id for
    READ_DEPARTURE.
    """

    time: float
    kind: str
    payload: Optional[Tuple] = None


class EventQueue:
    """Min-heap of events with a deterministic FIFO tie-break.

    Events at equal timestamps pop in insertion order (a monotone sequence
    number breaks ties), so a seeded simulation is reproducible regardless
    of float coincidences.
    """

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, (ev.time, next(self._seq), ev))

    def peek_time(self) -> float:
        return self._heap[0][0] if self._heap else float("inf")

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)
