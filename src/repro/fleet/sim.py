"""Discrete-event fleet simulator: concurrent regenerations over shared links.

The loop advances between events; repairs progress as fluid flows whose
rates are set by the fair-share link model (``sharing.py``).  Exogenous
events (failures, capacity shocks, degraded reads) live on a heap; repair
completions are *derived* each iteration from (remaining work x current
nominal duration), so share changes mid-repair are handled exactly — a
regeneration's duration emerges from contention instead of being read off
its plan.

Per event epoch, every repair that can start (queued slot, >= d healthy
providers, concurrency budget left) is planned in ONE call to the policy
with a stacked tensor of residual-capacity overlays — this is where the
PR-1 batched planning engine runs in throughput mode (many concurrent
repairs per call) rather than Monte-Carlo mode.

Failure model details:

* Poisson failures at ``failure_rate`` per healthy node; the aggregate
  exponential clock is re-drawn whenever the healthy population changes
  (memorylessness makes this exact for the Markov process).
* A failed slot's repair regenerates onto a replacement host in the same
  slot, so the capacity matrix is stable across repairs.
* If an active repair loses a provider to a new failure, it aborts: its
  links are released and the slot is requeued with its original failure
  time (the vulnerability window keeps accruing).  With
  ``Scenario.carryover`` on, the blocks already received from surviving
  providers travel with the queued slot as a per-link bank; re-admission
  keeps the surviving providers and credits the bank against the new
  plan's edge demands, so only the missing flows are re-transferred.
  With it off (default), the work is lost — the pre-PR-3 dynamics,
  bitwise.
* With ``Scenario.migration`` on, every capacity-shock and provider-loss
  epoch offers the in-flight repairs a re-plan through
  ``RepairPolicy.replan`` (one batched call, same engine path as
  admission); a proposal is accepted only if its banked-credited ETA under
  self-excluded shares beats the current one, so migration never extends a
  repair's expected finish at decision time.
* Data-loss accounting: every failure that leaves fewer than k healthy
  slots is a loss event; ``FleetMetrics`` additionally integrates the
  conditional ruin intensity for an MTTDL estimate that works at sane
  failure rates.

Plan-vs-reality robustness (ISSUE 6), all OFF by default:

* **Estimate error** (``Scenario.estimate_noise`` / ``estimate_refresh_
  period``): policies plan against a *believed* capacity matrix — a noisy,
  periodically-refreshed snapshot of the true effective capacities — while
  flows progress at true rates.  Predicted and realized ETAs diverge; the
  metrics record the plan-error distribution.
* **Straggler/stall injection** (``degrade_rate`` + Markov recovery, or
  deterministic ``degradations``): a live node's outgoing link rates are
  multiplied by a factor in [0, 1) without failing the host — invisible to
  the provider-loss abort path *and* to the believed matrix until the next
  estimate refresh (when estimates are off, the fresh believed view models
  plan-time capacities only: brownouts are data-plane faults monitoring
  never reports).
* **Watchdog + retry/backoff + graceful degradation**
  (``watchdog_period`` > 0): every period, each repair's banked progress
  is compared against its plan-predicted trajectory.  A repair below
  ``1/watchdog_lag`` of schedule (or outright stalled) gets escalating
  mitigation — first a credited in-place replan over the current believed
  capacities, then eviction of the straggling provider (bottleneck-link
  source) with the banked blocks carried over and a fresh helper drawn
  under exponential backoff, up to ``watchdog_retries`` times.  With
  ``degraded_d`` on, a repair that cannot find d healthy helpers is
  admitted with d' in [k, d) helpers (functional repair is sound for any
  d >= k, Dimakis et al. 0803.0632) instead of queueing forever.

Observability (ISSUE 7): with ``Scenario.trace`` on the simulator owns a
``repro.obs.FlightRecorder`` and emits the repair-lifecycle vocabulary —
``repair_queued`` (reason fail|abort|evict) / ``repair_admitted`` /
``repair_deferred`` / ``repair_abort`` / ``repair_evicted`` /
``repair_replan`` (kind migration|watchdog) / ``repair_complete`` plus
``watchdog_flag`` / ``watchdog_giveup``, node events (``node_fail`` /
``node_repaired`` / ``node_degrade`` / ``node_recover``), and
``data_loss`` / ``capacity_shock`` / ``estimate_refresh`` — while the
share model streams per-link occupancy into a ``LinkUsageTracer``.
Every emission site is guarded and none touches an rng stream, so traced
and untraced runs are bitwise identical (pinned by the goldens and
tests/test_obs.py): tracing is observation, not perturbation.

Coded data plane (ISSUE 10), OFF by default: with ``Scenario.dataplane``
on, degraded reads become real fragment transfers (``params.alpha``
blocks per source) progressing through the same fair-share fluid model
as repairs — ``read_duration`` is ignored — and every completed repair
replays its plan on an RLNC-coded store (``repro.storage.simulator``)
so the regenerated blocks can be decode-verified.  Per-link repair/read
bytes are ledgered in ``fleet.dataplane.DataPlane``; an optional
``Scenario.read_trace`` adds an open-loop arrival process on the
dedicated ``"data"`` rng stream.  Off, no coded store is allocated, no
extra rng is drawn, and every new code path is behind a ``dataplane is
None`` guard — the default instruction stream is unchanged (pinned by
the fleet golden).

Determinism: one root ``seed`` spawns named child streams (capacities,
failures, providers, reads, shocks, estimates, degrades, data plane) via
``np.random.default_rng([seed, stream])``, and all same-time events have
fixed precedence (repair completions, then read completions, then heap
order, then the Poisson failure clock, then the Poisson degrade clock),
so a run is bitwise reproducible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import CodeParams
from repro.obs import FlightRecorder, LinkUsageTracer

from .cluster import ClusterState
from .events import (CAPACITY_SHOCK, DEGRADE, ESTIMATE_REFRESH, Event,
                     EventQueue, FAILURE, READ_ARRIVAL, READ_DEPARTURE,
                     RECOVER, TRACE_READ, WATCHDOG)
from .metrics import FleetMetrics
from .policy import RepairPolicy
from .scenario import Scenario
from .sharing import (ActiveRepair, Link, LinkShareModel, apply_credit,
                      plan_links)

_STREAMS = {"caps": 0, "fail": 1, "prov": 2, "read": 3, "shock": 4,
            "est": 5, "degrade": 6, "data": 7}


class QueuedRepair(NamedTuple):
    """A slot awaiting (re-)admission.

    ``bank`` carries blocks already received per physical link when a
    carryover abort requeued the slot (None on a fresh failure);
    ``survivors`` are the aborted plan's still-useful providers, kept at
    re-admission so the banked links actually reappear in the new plan.
    ``avoid``/``retries``/``next_check`` travel with a slot the watchdog
    evicted a straggling provider from: evicted providers are not re-drawn
    while alternatives exist, the mitigation budget persists across the
    requeue, and the backoff clock is not reset by re-admission.
    ``rid`` is the flight-recorder repair id (ISSUE 7), assigned at the
    original failure and carried through every abort/eviction requeue so
    one lifecycle is one span tree; -1 when tracing is off.
    """

    fail_time: float
    node: int
    bank: Optional[Dict[Link, float]] = None
    survivors: Tuple[int, ...] = ()
    avoid: Tuple[int, ...] = ()
    retries: int = 0
    next_check: float = 0.0
    rid: int = -1


class FleetSimulator:
    """Simulate ``scenario`` under ``policy`` for one (n, k, d) code."""

    def __init__(self, scenario: Scenario, policy: RepairPolicy,
                 params: CodeParams, seed: int = 0,
                 check_shares: bool = False):
        if params.d > scenario.num_nodes - 1:
            raise ValueError(
                f"d={params.d} providers need a cluster of > d nodes, "
                f"got {scenario.num_nodes}")
        self.scenario = scenario
        self.policy = policy
        self.params = params
        self.seed = seed
        self.rng = {name: np.random.default_rng([seed, sid])
                    for name, sid in _STREAMS.items()}

        n = scenario.num_nodes
        base = np.asarray(scenario.capacity_model(self.rng["caps"], n),
                          dtype=np.float64)
        self.cluster = ClusterState(base, rack_size=scenario.rack_size)
        self.caps_base = self.cluster.caps.copy()
        # check_shares=True shadows every incremental share recompute with
        # the full-rescan oracle and asserts bitwise equality (slow; for
        # tests/debugging only)
        self.shares = LinkShareModel(self.cluster.caps, check=check_shares)

        # -- flight recorder (ISSUE 7): allocated only when asked for, and
        #    every emission site is guarded, so the default path runs the
        #    exact pre-observability instruction stream (no rng touched)
        self._rid_seq = 0
        self.recorder: Optional[FlightRecorder] = None
        self.link_tracer: Optional[LinkUsageTracer] = None
        if scenario.trace:
            self.recorder = FlightRecorder(
                capacity=scenario.trace_capacity,
                meta={"seed": seed, "num_nodes": n, "k": params.k,
                      "d": params.d, "duration": scenario.duration,
                      "policy": getattr(policy, "name", "?")})
            self.link_tracer = LinkUsageTracer(clock=lambda: self.now,
                                               recorder=self.recorder)
            self.shares.tracer = self.link_tracer

        self.now = 0.0
        self.queue: List[QueuedRepair] = []         # fail-time-ordered FIFO
        self.active: List[ActiveRepair] = []        # kept in start order
        self.reads: dict = {}
        self._reads_at: Dict[int, set] = {}     # node -> rids touching it
        self._indexed_rids: set = set()         # rids present in _reads_at
        self._read_seq = 0
        self._replan_pending = False
        self.loop_events = 0        # event epochs processed (perf metric)
        # (next event time, completion time, completion index, heap time,
        # read-completion time, read index) cached by _refresh_pending
        # after every step — this is what the lockstep ensemble driver
        # reads through next_event_time()
        self._pending: Tuple[float, float, int, float, float, int] = \
            (math.inf, math.inf, -1, math.inf, math.inf, -1)
        self._started = False

        # -- straggler/stall injection: per-node outgoing-rate multipliers.
        #    None (no degrade machinery configured) keeps the share model's
        #    arithmetic bitwise identical to the pre-robustness path.
        self.degrade: Optional[np.ndarray] = None
        self._degrade_gen = [0] * n      # stale-RECOVER supersession
        if scenario.degrade_rate > 0 or scenario.degradations:
            self.degrade = np.ones(n, dtype=np.float64)
            self.shares.out_mult = self.degrade

        # -- estimate error: the believed matrix policies plan against.
        #    None (no estimate machinery) aliases the true matrix — the
        #    perfectly-fresh default.
        self._estimates_on = (scenario.estimate_noise > 0
                              or scenario.estimate_refresh_period > 0)
        self.believed: Optional[np.ndarray] = None
        if self._estimates_on:
            self.believed = self.cluster.caps.copy()
            self.shares.believed = self.believed

        self.events = EventQueue()
        for t, node in sorted(scenario.failures):
            self.events.push(Event(t, FAILURE, (node,)))
        if scenario.shock_period > 0:
            self.events.push(Event(scenario.shock_period, CAPACITY_SHOCK))
        if scenario.read_rate > 0:
            self.events.push(Event(
                float(self.rng["read"].exponential(1.0 / scenario.read_rate)),
                READ_ARRIVAL))
        for t, node, factor, dur in sorted(scenario.degradations):
            self.events.push(Event(t, DEGRADE, (node, factor, dur)))
        if self._estimates_on:
            self._refresh_estimates()    # t=0 snapshot
            if scenario.estimate_refresh_period > 0:
                self.events.push(Event(scenario.estimate_refresh_period,
                                       ESTIMATE_REFRESH))
        if scenario.watchdog_period > 0:
            self.events.push(Event(scenario.watchdog_period, WATCHDOG))
        self.next_fail = self._draw_next_fail()
        self.next_degrade = self._draw_next_degrade()

        self.metrics = FleetMetrics(n=n, k=params.k,
                                    failure_rate=scenario.failure_rate)

        # -- coded data plane (ISSUE 10): allocated only when asked for;
        #    every touchpoint below is behind a ``dataplane is None`` guard
        #    and the "data" rng stream is drawn only here, so the default
        #    path keeps the exact pre-dataplane instruction stream
        self.dataplane = None
        self._trace_iter = None
        if scenario.dataplane:
            from .dataplane import DataPlane
            self.dataplane = DataPlane(scenario, params, self.shares,
                                       self.metrics, seed,
                                       recorder=self.recorder)
            self.metrics.dataplane = True
            if scenario.read_trace is not None:
                self._trace_iter = scenario.read_trace.arrivals(
                    self.rng["data"], scenario.duration)
                self._push_next_trace_read()

    # -- flight recorder helpers --------------------------------------------

    def _new_rid(self) -> int:
        """Next repair id.  Counted unconditionally (it is one integer
        increment and touches no rng), so traced and untraced runs agree
        on every id."""
        rid = self._rid_seq
        self._rid_seq += 1
        return rid

    def _emit_complete(self, r: ActiveRepair) -> None:
        """Called with ``r``'s links still acquired: the bottleneck is
        judged under the shares the repair actually finished at."""
        worst, worst_t = None, -1.0
        for link, f in r.links:
            s = self.shares.share(link)
            t = f / s if s > 0.0 else math.inf
            if worst is None or t > worst_t:
                worst, worst_t = link, t
        realized = self.now - r.plan_t0
        err = (realized / r.predicted - 1.0
               if math.isfinite(r.predicted) and r.predicted > 0 else None)
        self.recorder.emit(self.now, "repair_complete", rid=r.rid,
                           node=r.node, realized=realized,
                           predicted=r.predicted, plan_err=err,
                           regen=self.now - r.start_time,
                           wait=r.start_time - r.fail_time,
                           bottleneck=list(worst) if worst else None)
        self.recorder.emit(self.now, "node_repaired", node=r.node)

    # -- stochastic clocks --------------------------------------------------

    def _draw_next_fail(self) -> float:
        rate = self.scenario.failure_rate * self.cluster.num_healthy
        if rate <= 0:
            return math.inf
        return self.now + float(self.rng["fail"].exponential(1.0 / rate))

    def _draw_next_degrade(self) -> float:
        """Aggregate brownout clock.  Every slot's NIC is eligible
        regardless of health state (a brownout is a link-level fault, not
        a storage fault), so the rate is constant and the clock never
        needs redrawing on failures — the degrade stream stays independent
        of every other stream."""
        rate = self.scenario.degrade_rate * self.scenario.num_nodes
        if rate <= 0:
            return math.inf
        return self.now + float(self.rng["degrade"].exponential(1.0 / rate))

    # -- straggler/stall injection ------------------------------------------

    def _apply_degrade(self, node: int, factor: float,
                       duration: float) -> None:
        """Multiply ``node``'s outgoing link rates by ``factor`` for
        ``duration`` seconds.  Silent: no abort, no replan offer — only
        actual flow rates change (the run loop recomputes nominals every
        iteration).  A re-degrade supersedes the pending recovery via the
        generation counter."""
        assert self.degrade is not None
        self.degrade[node] = factor
        self.shares.invalidate_source(node)
        self._degrade_gen[node] += 1
        self.events.push(Event(self.now + duration, RECOVER,
                               (node, self._degrade_gen[node])))
        self.metrics.on_degrade()
        if self.recorder is not None:
            self.recorder.emit(self.now, "node_degrade", node=node,
                               factor=factor, duration=duration)

    def _poisson_degrade(self) -> None:
        sc = self.scenario
        rngd = self.rng["degrade"]
        victim = int(rngd.integers(sc.num_nodes))
        factor = float(rngd.uniform(sc.degrade_lo, sc.degrade_hi))
        duration = float(rngd.exponential(sc.degrade_mean_duration))
        self._apply_degrade(victim, factor, duration)
        self.next_degrade = self._draw_next_degrade()

    def _recover(self, node: int, gen: int) -> None:
        if self.degrade is not None and self._degrade_gen[node] == gen:
            self.degrade[node] = 1.0
            self.shares.invalidate_source(node)
            if self.recorder is not None:
                self.recorder.emit(self.now, "node_recover", node=node)

    # -- estimate error -----------------------------------------------------

    def _refresh_estimates(self) -> None:
        """Re-snapshot the believed matrix from the true effective
        capacities (shocks *and* brownouts included — monitoring measures
        achieved rates), multiplied by per-link U[1-e, 1+e] noise.
        Between refreshes the belief goes stale: shocks and brownouts that
        happen after the snapshot are invisible to the planner."""
        assert self.believed is not None
        eff = self.cluster.caps
        if self.degrade is not None:
            eff = eff * self.degrade[:, None]
        noise = self.scenario.estimate_noise
        if noise > 0:
            mult = self.rng["est"].uniform(1.0 - noise, 1.0 + noise,
                                           size=eff.shape)
            self.believed[:] = eff * mult
        else:
            self.believed[:] = eff
        np.fill_diagonal(self.believed, 0.0)
        if self.recorder is not None:
            self.recorder.emit(self.now, "estimate_refresh")

    # -- event handlers -----------------------------------------------------

    def _apply_failure(self, node: int) -> bool:
        """Fail ``node``; returns whether the healthy population actually
        changed (False for a redundant injection on an already-down slot,
        in which case the caller must NOT redraw the Poisson clock — a
        no-op redraw would shift the rng stream and break seeded
        comparability between scenarios that differ only in a redundant
        injection)."""
        if self.cluster.state[node] != 0:       # already failed / repairing
            return False
        self.cluster.fail(node)
        if self.recorder is not None:
            self.recorder.emit(self.now, "node_fail", node=node)
        if self.cluster.num_healthy < self.params.k:
            self.metrics.on_data_loss()
            if self.recorder is not None:
                self.recorder.emit(self.now, "data_loss",
                                   unavailable=self.cluster.num_unavailable)
        self.queue.append(QueuedRepair(self.now, node, rid=self._new_rid()))
        if self.recorder is not None:
            self.recorder.emit(self.now, "repair_queued",
                               rid=self.queue[-1].rid, node=node,
                               reason="fail")
        # tear down degraded reads touching the failed node: their links
        # must not linger as phantom flows until the scheduled departure
        # (the stale READ_DEPARTURE becomes a no-op when it fires).  The
        # node -> rids index replaces the all-reads scan; sorting restores
        # the arrival (dict insertion) order the scan released in.  Reads
        # injected directly into ``self.reads`` (tests craft these) bypass
        # the index, so fall back to the scan unless it covers every read
        if len(self._indexed_rids) == len(self.reads):
            dead_reads = sorted(self._reads_at.get(node, ()))
        else:
            dead_reads = [rid for rid, links in self.reads.items()
                          if any(node in link for link, _ in links)]
        for rid in dead_reads:
            links = self.reads.pop(rid)
            self.shares.release(links)
            self._unindex_read(rid, links)
        # data-plane reads touching the node die too (partial fragment
        # bytes already on the wire stay in the read ledger)
        if self.dataplane is not None:
            self.dataplane.teardown_node(node, self.now)
        # abort in-flight repairs that lost a provider.  node is healthy
        # until this failure while every r.ids[0] slot is REPAIRING, so
        # membership in ids is membership in the providers tail
        lost = [i for i, r in enumerate(self.active) if node in r.ids]
        for i in reversed(lost):
            r = self.active.pop(i)
            if self.dataplane is not None:
                # the delivered fraction of this segment crossed the wire;
                # ledger it before release/rebase destroy the progress state
                self.dataplane.account_repair_wire(r, 1.0 - r.remaining)
            self.shares.release(r.links, r)
            self.cluster.abort_repair(r.node)
            if self.scenario.carryover:
                # keep blocks already received — except those parked at the
                # failed provider itself, which died with its host.  Blocks
                # it *sent* have already landed downstream and survive.
                bank = {link: b for link, b in r.banked_now().items()
                        if link[1] != node}
                survivors = tuple(p for p in r.providers if p != node)
                self.queue.append(QueuedRepair(r.fail_time, r.node,
                                               bank, survivors, rid=r.rid))
                self.metrics.on_abort(carryover=True)
            else:
                self.queue.append(QueuedRepair(r.fail_time, r.node,
                                               rid=r.rid))
                self.metrics.on_abort(carryover=False)
            if self.recorder is not None:
                self.recorder.emit(self.now, "repair_abort", rid=r.rid,
                                   node=r.node, lost_provider=node,
                                   carryover=self.scenario.carryover)
                self.recorder.emit(self.now, "repair_queued", rid=r.rid,
                                   node=r.node, reason="abort")
        if lost:
            # requeued aborts carry older fail_times than the failure that
            # evicted them; restore oldest-first admission order (stable on
            # ties, so same-time entries keep insertion order)
            self.queue.sort(key=lambda q: q.fail_time)
            self._replan_pending = True
        # banked blocks sitting *at* the failed node are gone for queued
        # repairs too (the host is replaced before it can relay them on)
        for i, q in enumerate(self.queue):
            if q.bank and any(link[1] == node for link in q.bank):
                self.queue[i] = q._replace(
                    bank={l: b for l, b in q.bank.items() if l[1] != node})
        return True

    def _poisson_failure(self) -> None:
        healthy = self.cluster.healthy_nodes()
        if healthy:
            # integers(0, n) consumes the identical stream draw as the
            # uniform scalar choice(n) it replaces, minus its array setup
            victim = int(self.rng["fail"].integers(0, len(healthy)))
            victims = [healthy[victim]]
            sc = self.scenario
            if (sc.rack_size > 0 and sc.rack_burst_prob > 0
                    and self.rng["fail"].random() < sc.rack_burst_prob):
                peers = [p for p in self.cluster.rack_peers(victims[0])
                         if self.cluster.state[p] == 0]
                extra = min(sc.rack_burst_extra, len(peers))
                if extra:
                    idx = self.rng["fail"].choice(len(peers), size=extra,
                                                  replace=False)
                    victims += [peers[int(i)] for i in idx]
            for v in victims:
                self._apply_failure(v)
        self.next_fail = self._draw_next_fail()

    def _capacity_shock(self) -> None:
        sc = self.scenario
        n = sc.num_nodes
        mult = self.rng["shock"].uniform(sc.shock_lo, sc.shock_hi,
                                         size=(n, n))
        self.cluster.caps[:] = self.caps_base * mult
        np.fill_diagonal(self.cluster.caps, 0.0)
        self.events.push(Event(self.now + sc.shock_period, CAPACITY_SHOCK))
        self._replan_pending = True
        if self.recorder is not None:
            self.recorder.emit(self.now, "capacity_shock")

    def _read_arrival(self) -> None:
        """Closed-loop degraded read (legacy ``read_rate`` path): only fires
        while a slot is down.  With the data plane on, the identical rng
        draws pick the endpoints, then the read becomes a fragment-transfer
        flow (completion from contention; ``read_duration`` ignored)
        instead of a fixed-duration phantom."""
        sc = self.scenario
        healthy = self.cluster.healthy_nodes()
        fanin = sc.read_fanin or self.params.k
        if self.cluster.num_unavailable > 0 and len(healthy) > fanin:
            dst_i = int(self.rng["read"].integers(0, len(healthy)))  # == choice(n)
            dst = healthy[dst_i]
            # index remap stands in for the dst-excluding pool listcomp:
            # pool[i] == healthy[i] for i < dst_i else healthy[i + 1],
            # so the rng draw below sees the identical pool size
            idx = self.rng["read"].choice(len(healthy) - 1, size=fanin,
                                          replace=False)
            picked = [healthy[j if j < dst_i else j + 1]
                      for j in (int(i) for i in idx)]
            if self.dataplane is not None:
                self.dataplane.start_read(self.now, dst, picked)
            else:
                links = [((src, dst), 1.0) for src in picked]
                self.shares.acquire(links)
                rid = self._read_seq
                self._read_seq += 1
                self.reads[rid] = links
                self._index_read(rid, links)
                self.events.push(Event(self.now + sc.read_duration,
                                       READ_DEPARTURE, (rid,)))
        self.events.push(Event(
            self.now + float(self.rng["read"].exponential(1.0 / sc.read_rate)),
            READ_ARRIVAL))

    def _push_next_trace_read(self) -> None:
        """Pull the next open-loop arrival lazily (one at a time, so file
        traces of millions of reads never materialize in memory)."""
        t = next(self._trace_iter, None)
        if t is not None:
            self.events.push(Event(float(t), TRACE_READ))

    def _trace_read_arrival(self) -> None:
        """Open-loop trace read (ISSUE 10 satellite semantics): served
        whenever >= fanin + 1 healthy nodes exist — degraded or not, an
        open-loop user read always fetches its fragments — and *dropped*
        (counted, recorded) otherwise.  Contrast ``_read_arrival``, whose
        closed-loop reads model degraded-slot reconstruction and only fire
        while a slot is down.  Endpoint draws come from the dedicated
        "data" stream, so trace mode never shifts the legacy read stream."""
        dp = self.dataplane
        healthy = self.cluster.healthy_nodes()
        if len(healthy) > dp.fanin:
            rngd = self.rng["data"]
            dst_i = int(rngd.integers(0, len(healthy)))
            dst = healthy[dst_i]
            idx = rngd.choice(len(healthy) - 1, size=dp.fanin, replace=False)
            picked = [healthy[j if j < dst_i else j + 1]
                      for j in (int(i) for i in idx)]
            dp.start_read(self.now, dst, picked)
        else:
            self.metrics.on_read_drop()
            if self.recorder is not None:
                self.recorder.emit(self.now, "read_drop",
                                   healthy=len(healthy), fanin=dp.fanin)
        self._push_next_trace_read()

    def _read_departure(self, rid: int) -> None:
        links = self.reads.pop(rid, None)
        if links is not None:
            self.shares.release(links)
            self._unindex_read(rid, links)

    def _index_read(self, rid: int, links) -> None:
        at = self._reads_at
        for (src, dst), _ in links:
            at.setdefault(src, set()).add(rid)
            at.setdefault(dst, set()).add(rid)
        self._indexed_rids.add(rid)

    def _unindex_read(self, rid: int, links) -> None:
        at = self._reads_at
        for (src, dst), _ in links:
            s = at.get(src)
            if s is not None:
                s.discard(rid)
            s = at.get(dst)
            if s is not None:
                s.discard(rid)
        self._indexed_rids.discard(rid)

    # -- repair admission ---------------------------------------------------

    def _pick_providers(self, failed: int, healthy: List[int],
                        survivors: Sequence[int] = (),
                        d: Optional[int] = None,
                        avoid: Sequence[int] = ()) -> List[int]:
        """Choose ``d`` providers (default ``params.d``).  ``survivors``
        (still-healthy providers of a carryover-aborted plan) are kept so
        the banked links can be re-credited, and only the deficit is drawn
        fresh; with no survivors the draw is identical to the
        pre-carryover uniform sample.  ``avoid`` names watchdog-evicted
        stragglers: they are excluded from the fresh draw while enough
        alternatives exist (best effort — with a thin pool they come back
        into play rather than starving the repair)."""
        if d is None:
            d = self.params.d
        if self.scenario.provider_picker is not None:
            return list(self.scenario.provider_picker(failed, healthy,
                                                      self.rng["prov"]))
        alive = self.cluster.healthy_set()
        keep = [s for s in survivors if s in alive][:d]
        deficit = d - len(keep)
        if not deficit:
            return keep
        # no survivors (the common case): healthy itself is the pool
        # (read-only cached list, never mutated here)
        pool = healthy if not keep else [h for h in healthy if h not in keep]
        if avoid:
            trimmed = [h for h in pool if h not in avoid]
            if len(trimmed) >= deficit:
                pool = trimmed
        idx = self.rng["prov"].choice(len(pool), size=deficit,
                                      replace=False)
        return keep + [pool[int(i)] for i in idx]

    def _drain_queue(self) -> None:
        """Start every currently-startable repair, planned as one batch.

        A repair whose plan comes back with infinite time (it was routed
        over a zero-capacity link) must not start: it would hold its links
        and a ``max_concurrent`` slot forever under static capacities.  It
        is excluded from this epoch's batch and requeued — a later epoch
        (new providers, restored capacity) gets to retry it.  Deferral
        frees the admission slots it held, so the collection loop runs
        again for the rest of the queue; with no dead overlays (the normal
        case) exactly one batched planning call is made per epoch.
        """
        if not self.queue:
            return              # nothing admissible: skip the batch setup
        deferred: List[QueuedRepair] = []
        sc = self.scenario
        while True:
            startable: List[Tuple[QueuedRepair, List[int], CodeParams]] = []
            while (self.queue
                   and len(self.active) + len(startable)
                   < sc.max_concurrent):
                healthy = self.cluster.healthy_nodes()
                d_eff = self.params.d
                if len(healthy) < d_eff:
                    if sc.degraded_d and len(healthy) >= self.params.k:
                        # graceful degradation: functional repair with
                        # d' = |healthy| in [k, d) helpers instead of
                        # queueing until the population recovers
                        d_eff = len(healthy)
                    else:
                        break
                q = self.queue.pop(0)
                self.cluster.start_repair(q.node)
                try:
                    ids = [q.node] + self._pick_providers(
                        q.node, healthy, q.survivors, d_eff, q.avoid)
                    if len(set(ids)) != d_eff + 1:
                        raise ValueError(
                            f"provider picker returned {ids[1:]} for slot "
                            f"{q.node}: need {d_eff} distinct providers "
                            f"!= the slot")
                except Exception:
                    # roll back every slot this batch already flipped to
                    # REPAIRING (no ActiveRepair exists for them yet) and
                    # restore the queue, so a picker error leaves the
                    # cluster consistent instead of slots wedged in
                    # REPAIRING with no repair that could ever finish
                    self.cluster.abort_repair(q.node)
                    for qq, _, _ in startable:
                        self.cluster.abort_repair(qq.node)
                    self.queue = ([qq for qq, _, _ in startable] + [q]
                                  + self.queue + deferred)
                    raise
                params_eff = (self.params if d_eff == self.params.d else
                              dataclasses.replace(self.params, d=d_eff))
                startable.append((q, ids, params_eff))
            if not startable:
                break
            # one batched planning call per distinct repair fan-out — one
            # call total on the default path (degraded-d admissions only
            # happen when the cluster is nearly dead)
            by_d: Dict[int, List[int]] = {}
            for i, (_, ids, _) in enumerate(startable):
                by_d.setdefault(len(ids) - 1, []).append(i)
            plans: list = [None] * len(startable)
            for d_eff in sorted(by_d):
                rows = by_d[d_eff]
                overlays = self.shares.residual_overlays(
                    [startable[i][1] for i in rows])
                got = self.policy.plan_batch(overlays, startable[rows[0]][2])
                for i, plan in zip(rows, got):
                    plans[i] = plan
            num_deferred = 0
            for (q, ids, params_eff), plan in zip(startable, plans):
                if not math.isfinite(plan.time):
                    self.cluster.abort_repair(q.node)   # back to FAILED
                    deferred.append(q)
                    num_deferred += 1
                    if self.recorder is not None:
                        self.recorder.emit(self.now, "repair_deferred",
                                           rid=q.rid, node=q.node)
                    continue
                flows = plan_links(plan, ids)
                if q.bank:
                    links, credited, total = apply_credit(flows, q.bank)
                    self.metrics.on_carryover(credited, total)
                    bank = dict(q.bank)
                else:
                    links, bank = flows, {}
                # the ETA this plan promises under the believed capacities
                # at its own admission instant — the realized duration is
                # measured against it (plan-error distribution)
                predicted = self.shares.admission_time(links)
                r = ActiveRepair(
                    node=q.node, plan=plan, ids=list(ids), links=links,
                    fail_time=q.fail_time, start_time=self.now, bank=bank,
                    plan_t0=self.now, predicted=predicted,
                    retries=q.retries, next_check=q.next_check,
                    avoid=q.avoid, rid=q.rid)
                self.shares.acquire(links, r)
                if len(ids) - 1 < self.params.d:
                    self.metrics.on_degraded_admission()
                self.active.append(r)
                if self.recorder is not None:
                    self.recorder.emit(
                        self.now, "repair_admitted", rid=q.rid, node=q.node,
                        scheme=plan.scheme, d=len(ids) - 1,
                        helpers=[int(h) for h in ids[1:]],
                        banked=float(sum(bank.values())) if bank else 0.0,
                        predicted=predicted,
                        degraded=len(ids) - 1 < self.params.d)
            if not num_deferred:
                break
        if deferred:
            self.queue.extend(deferred)
            self.queue.sort(key=lambda q: q.fail_time)

    # -- in-flight plan migration -------------------------------------------

    def _maybe_replan(self) -> None:
        """Offer every in-flight repair a migration (one batched
        ``policy.replan`` call), accepting a proposal only if its
        banked-credited ETA beats the current one.

        Caller guarantees nominals are fresh (``shares.recompute``).  Each
        proposal is evaluated under self-excluded shares — the repair's own
        occupancy is discounted, so staying on a link costs what it costs
        today and leaving one frees it.  Like admission, proposals are a
        same-epoch snapshot: an accepted migration changes the shares its
        successors are judged under (we recompute between accepts), but the
        overlays the policy planned against are not re-stacked.

        With ``Scenario.bank_aware_migration`` on (ISSUE 8) the policy
        returns *every* candidate plan per repair
        (``replan_candidates``) and the simulator picks the one
        minimizing the banked-credited ETA, so a tree overlapping
        already-received blocks can beat the nominally-fastest tree.  Off
        (default) the single ``replan`` proposal goes through the same
        scoring, which degenerates to the pre-ISSUE-8 accept test bitwise.
        """
        bank_aware = self.scenario.bank_aware_migration
        groups: Dict[int, List[ActiveRepair]] = {}
        for r in self.active:
            groups.setdefault(len(r.ids) - 1, []).append(r)
        for d_eff in sorted(groups):
            params_eff = (self.params if d_eff == self.params.d else
                          dataclasses.replace(self.params, d=d_eff))
            group = groups[d_eff]
            overlays = self.shares.residual_overlays(
                [r.ids for r in group],
                excludes=[frozenset(l for l, _ in r.links) for r in group])
            if bank_aware:
                cand_lists = self.policy.replan_candidates(overlays,
                                                           params_eff)
            else:
                cand_lists = [[p] for p in
                              self.policy.replan(overlays, params_eff)]
            for r, plans in zip(group, cand_lists):
                best = self._best_candidate(r, plans)
                if best is None:
                    continue
                plan, links, bank, credited, total, eta_new = best
                if eta_new >= r.eta():
                    continue
                if self.dataplane is not None:
                    self.dataplane.account_repair_wire(r, 1.0 - r.remaining)
                self.shares.release(r.links, r)
                r.rebase(plan, links, bank)
                self.shares.acquire(r.links, r)
                r.plan_t0 = self.now
                r.predicted = eta_new
                self.metrics.on_migration(credited, total)
                if self.recorder is not None:
                    self.recorder.emit(self.now, "repair_replan", rid=r.rid,
                                       node=r.node, kind="migration",
                                       scheme=plan.scheme, credited=credited,
                                       total=total, predicted=eta_new)
                self.shares.recompute(self._contending())

    def _best_candidate(self, r: ActiveRepair, plans: Sequence,
                        ) -> Optional[tuple]:
        """Score replacement-plan candidates for in-flight repair ``r`` by
        *credited* ETA under self-excluded shares — banked blocks are
        subtracted from each candidate's demands first, so overlap with
        already-received work counts for exactly what it saves.  Returns
        the winning ``(plan, links, bank, credited, total, eta)`` or
        ``None``; the first minimum wins ties (candidate order is the
        policy's scheme preference), keeping the choice deterministic."""
        occupied = frozenset(l for l, _ in r.links)
        bank = r.banked_now()
        best = None
        for plan in plans:
            if plan is None or not math.isfinite(plan.time):
                continue
            links, credited, total = apply_credit(
                plan_links(plan, r.ids), bank)
            eta = self.shares.admission_time(links, exclude=occupied)
            if best is None or eta < best[5]:
                best = (plan, links, bank, credited, total, eta)
        return best

    # -- watchdog: plan-vs-reality mitigation -------------------------------

    def _watchdog(self) -> None:
        """Flag every in-flight repair whose realized progress trails its
        plan-predicted trajectory by more than ``watchdog_lag``x — or whose
        ETA is outright infinite (a stall; the ratio test alone would never
        flag a 90%-done repair whose last link browned out to zero) — and
        escalate mitigation.  Repairs inside their backoff window
        (``next_check``) are skipped, including given-up ones
        (``next_check == inf``)."""
        sc = self.scenario
        for r in list(self.active):
            if self.now < r.next_check:
                continue
            elapsed = self.now - r.plan_t0
            if elapsed <= 0.0:
                continue
            stalled = not math.isfinite(r.eta())
            done = 1.0 - r.remaining
            expected = (min(1.0, elapsed / r.predicted)
                        if math.isfinite(r.predicted) and r.predicted > 0
                        else 0.0)
            if stalled or done * sc.watchdog_lag < expected:
                self.metrics.on_watchdog_flag()
                if self.recorder is not None:
                    self.recorder.emit(self.now, "watchdog_flag", rid=r.rid,
                                       node=r.node, stalled=stalled,
                                       done=done, expected=expected)
                self._mitigate(r)
        self.events.push(Event(self.now + sc.watchdog_period, WATCHDOG))

    def _mitigate(self, r: ActiveRepair) -> None:
        """Escalating mitigation ladder for a flagged repair.

        Attempt 0 is a credited in-place replan over the current believed
        capacities; attempts 1..``watchdog_retries`` evict the straggling
        provider and retry with a fresh helper (so the budget buys one
        rescue replan plus ``watchdog_retries`` evictions).  Each attempt
        pushes the next check out by ``watchdog_period * backoff^attempt``;
        past the budget the repair is left to limp along at whatever rate
        it gets, and further flags are suppressed (``next_check = inf``).
        The attempt counter lives on the repair and survives eviction
        requeues, so a chronically lagging slot cannot reset its own
        budget by being mitigated."""
        sc = self.scenario
        attempt = r.retries
        if attempt > sc.watchdog_retries:
            self.metrics.on_watchdog_giveup()
            if self.recorder is not None:
                self.recorder.emit(self.now, "watchdog_giveup", rid=r.rid,
                                   node=r.node, retries=attempt)
            r.next_check = math.inf
            return
        r.retries = attempt + 1
        r.next_check = (self.now
                        + sc.watchdog_period * sc.watchdog_backoff ** attempt)
        if attempt == 0:
            self._watchdog_replan(r)
        else:
            self._evict_straggler(r)

    def _watchdog_replan(self, r: ActiveRepair) -> None:
        """Rescue attempt 0: a single-row ``policy.replan`` over the
        repair's self-excluded believed overlay, accepted only if the
        banked-credited ETA beats the current one.  Unlike opportunistic
        migration this runs even with ``Scenario.migration`` off — it is a
        targeted rescue.  Note both ETAs are believed-view predictions: a
        replan can be accepted and still be stalled in reality (the
        believed map does not know about the brownout), in which case the
        next flag escalates to eviction."""
        d_eff = len(r.ids) - 1
        params_eff = (self.params if d_eff == self.params.d else
                      dataclasses.replace(self.params, d=d_eff))
        occupied = frozenset(l for l, _ in r.links)
        overlay = self.shares.residual_overlay(r.ids, exclude=occupied)
        if self.scenario.bank_aware_migration:
            cands = self.policy.replan_candidates(overlay[None, ...],
                                                  params_eff)
            plans = cands[0] if cands else []
        else:
            proposals = self.policy.replan(overlay[None, ...], params_eff)
            plans = [proposals[0]] if proposals else []
        best = self._best_candidate(r, plans)
        if best is None:
            return
        plan, links, bank, credited, total, eta_new = best
        if eta_new >= r.eta():
            return
        if self.dataplane is not None:
            self.dataplane.account_repair_wire(r, 1.0 - r.remaining)
        self.shares.release(r.links, r)
        r.rebase(plan, links, bank)
        self.shares.acquire(r.links, r)
        r.plan_t0 = self.now
        r.predicted = eta_new
        self.metrics.on_watchdog_replan(credited, total)
        if self.recorder is not None:
            self.recorder.emit(self.now, "repair_replan", rid=r.rid,
                               node=r.node, kind="watchdog",
                               scheme=plan.scheme, credited=credited,
                               total=total, predicted=eta_new)
        self.shares.recompute(self._contending())

    def _evict_straggler(self, r: ActiveRepair) -> None:
        """Evict the provider feeding the repair's bottleneck link —
        judged under *true* shares, because the watchdog observes achieved
        rates, not the believed map — and requeue the slot with its banked
        blocks, surviving providers, and an ``avoid`` entry so re-admission
        draws a fresh helper.  Mirrors the provider-loss carryover abort:
        blocks parked *at* the evicted provider leave the plan with it
        (it is no longer part of the tree to relay them), blocks it already
        sent have landed downstream and stay banked."""
        worst_link, worst_t = None, -1.0
        for link, f in r.links:
            if link[0] == r.node:
                continue                    # never evict the newcomer
            s = self.shares.share(link)
            t = f / s if s > 0.0 else math.inf
            if worst_link is None or t > worst_t:
                worst_link, worst_t = link, t
        if worst_link is None:              # no evictable residual links
            return
        straggler = worst_link[0]
        if self.dataplane is not None:
            self.dataplane.account_repair_wire(r, 1.0 - r.remaining)
        self.shares.release(r.links, r)
        self.active.remove(r)
        self.cluster.abort_repair(r.node)
        bank = {link: b for link, b in r.banked_now().items()
                if link[1] != straggler}
        survivors = tuple(p for p in r.providers if p != straggler)
        self.queue.append(QueuedRepair(
            r.fail_time, r.node, bank, survivors,
            avoid=r.avoid + (straggler,), retries=r.retries,
            next_check=r.next_check, rid=r.rid))
        self.queue.sort(key=lambda q: q.fail_time)
        self.metrics.on_eviction()
        if self.recorder is not None:
            self.recorder.emit(self.now, "repair_evicted", rid=r.rid,
                               node=r.node, straggler=straggler,
                               banked=float(sum(bank.values())))
            self.recorder.emit(self.now, "repair_queued", rid=r.rid,
                               node=r.node, reason="evict")

    # -- main loop ----------------------------------------------------------

    def _contending(self) -> List[ActiveRepair]:
        """Every flow the share engine must keep fresh: active repairs
        plus in-flight data-plane reads (one population — the incremental
        engine refreshes exactly the items passed here, and its
        registration-count fast path compares against this list's
        length).  With the data plane off this IS ``self.active``, so the
        default path passes the identical object it always did."""
        dp = self.dataplane
        if dp is None or not dp.reads:
            return self.active
        return self.active + dp.reads

    def _next_completion(self) -> Tuple[float, int]:
        """(absolute time, index into self.active) of the earliest finishing
        repair; on ties the strict < keeps the first hit, and ``active`` is
        in start order, so the earliest-started repair wins.  ``eta`` is
        inlined — this scan runs every event epoch."""
        best_t, best_i = math.inf, -1
        now = self.now
        for i, r in enumerate(self.active):
            rem = r.remaining
            t = now + rem * r.nominal if rem > 0.0 else now
            if t < best_t:
                best_t, best_i = t, i
        return best_t, best_i

    def _advance(self, t: float) -> None:
        dt = t - self.now
        # inlined ActiveRepair.advance (same arithmetic, pinned by the
        # goldens): only a finite positive nominal accrues progress, and a
        # zero nominal (degenerate all-tiny-flow plan) finishes outright
        if dt < 0:
            raise ValueError(f"negative time step {dt}")
        if dt == 0.0:
            # same-epoch advance: rem - 0.0/nom == rem bitwise, so only the
            # degenerate zero-nominal finish-outright branch has any effect
            for r in self.active:
                if r.nominal == 0.0:
                    r.remaining = 0.0
        else:
            for r in self.active:
                nom = r.nominal
                if nom > 0.0 and nom != math.inf:
                    rem = r.remaining - dt / nom
                    r.remaining = rem if rem > 0.0 else 0.0
                elif nom == 0.0:
                    r.remaining = 0.0
        if self.dataplane is not None:
            self.dataplane.advance_reads(dt)
        self.now = t
        self.metrics.observe(t, len(self.queue) + len(self.active),
                             self.cluster.num_unavailable)

    def _complete(self, i: int) -> None:
        r = self.active.pop(i)
        if self.recorder is not None:
            self._emit_complete(r)          # before releasing the links
        if self.dataplane is not None:
            # the final segment delivered in full; ledger its wire bytes,
            # then replay the plan on the coded store (provider encode /
            # interior relay / newcomer regenerate) and optionally
            # decode-verify the regenerated node
            self.dataplane.account_repair_wire(r, 1.0)
            self.dataplane.on_repair_complete(r, self.now)
        r.remaining = 0.0
        self.shares.release(r.links, r)
        self.cluster.complete_repair(r.node)
        self.metrics.on_complete(r.fail_time, r.start_time, self.now,
                                 r.plan_t0, r.predicted)
        # the healthy population grew: re-draw the aggregate failure clock
        # (memorylessness makes the re-draw exact, same as on failures)
        self.next_fail = self._draw_next_fail()

    def _complete_read(self, ri: int) -> None:
        self.dataplane.complete_read(ri, self.now)

    def _refresh_pending(self) -> None:
        """Cache (next event time, completion time, completion index, heap
        time) for the next :meth:`step`.  Nothing can change simulator
        state between the end of one step and the start of the next, so
        computing this once per step (instead of at the top of each loop
        iteration) is exact — and it is what exposes
        :meth:`next_event_time` to the lockstep ensemble driver without
        re-scanning the active set."""
        t_comp, ci = self._next_completion()
        if self.dataplane is not None:
            t_read, ri = self.dataplane.next_read_completion(self.now)
        else:
            t_read, ri = math.inf, -1
        t_exo = self.events.peek_time()
        t_next = min(t_comp, t_read, t_exo, self.next_fail,
                     self.next_degrade)
        self._pending = (t_next, t_comp, ci, t_exo, t_read, ri)

    def next_event_time(self) -> float:
        """Absolute time of the next event epoch (``inf`` when idle) —
        valid after :meth:`start` and between :meth:`step` calls.  The
        ensemble driver keys its lockstep heap on this."""
        return self._pending[0]

    def start(self) -> None:
        """Prime the loop: t=0 observation, initial admissions, shares.
        Idempotent guard so ``run()`` after a manual ``start()`` works."""
        if self._started:
            return
        self._started = True
        self.metrics.observe(0.0, len(self.queue) + len(self.active),
                             self.cluster.num_unavailable)
        self._drain_queue()
        self.shares.recompute(self._contending())
        self._refresh_pending()

    def step(self) -> bool:
        """Process one event epoch; returns False once the horizon is
        reached (the final advance to ``duration`` has then been made).
        ``run()`` is ``start(); while step(): pass`` — the split lets the
        ensemble driver interleave many simulators in lockstep."""
        end = self.scenario.duration
        t_next, t_comp, ci, t_exo, t_read, ri = self._pending
        if t_next > end or not math.isfinite(t_next):
            self._advance(end)
            return False
        self.loop_events += 1
        self._advance(t_next)
        # fixed same-time precedence: repair completion, read completion,
        # heap, Poisson failure clock, Poisson degrade clock (with the
        # data plane off t_read is inf, so the dispatch reduces to the
        # pre-dataplane chain bitwise)
        if (t_comp <= t_read and t_comp <= t_exo
                and t_comp <= self.next_fail
                and t_comp <= self.next_degrade):
            self._complete(ci)
        elif (t_read <= t_exo and t_read <= self.next_fail
                and t_read <= self.next_degrade):
            self._complete_read(ri)
        elif t_exo <= self.next_fail and t_exo <= self.next_degrade:
            ev = self.events.pop()
            if ev.kind == FAILURE:
                if self._apply_failure(ev.payload[0]):
                    # redraw only when the healthy population actually
                    # changed; a redundant injection must not shift the
                    # Poisson stream (memorylessness keeps the old draw
                    # exact when the rate is unchanged)
                    self.next_fail = self._draw_next_fail()
            elif ev.kind == CAPACITY_SHOCK:
                self._capacity_shock()
                # a shock epoch rewrites the capacity matrix in place —
                # overridden shocks (tests subclass the hook) included, so
                # the invalidation lives at the dispatch site, not inside
                # the default implementation
                self.shares.invalidate_all()
            elif ev.kind == READ_ARRIVAL:
                self._read_arrival()
            elif ev.kind == READ_DEPARTURE:
                self._read_departure(ev.payload[0])
            elif ev.kind == TRACE_READ:
                self._trace_read_arrival()
            elif ev.kind == DEGRADE:
                self._apply_degrade(*ev.payload)
            elif ev.kind == RECOVER:
                self._recover(*ev.payload)
            elif ev.kind == ESTIMATE_REFRESH:
                self._refresh_estimates()
                self.events.push(Event(
                    self.now + self.scenario.estimate_refresh_period,
                    ESTIMATE_REFRESH))
            elif ev.kind == WATCHDOG:
                self._watchdog()
        elif self.next_fail <= self.next_degrade:
            self._poisson_failure()
        else:
            self._poisson_degrade()
        if (self._estimates_on
                and self.scenario.estimate_refresh_period == 0):
            # period 0 = perfectly fresh (but still noisy) estimates:
            # re-snapshot every epoch so the noise alone is the error
            self._refresh_estimates()
        if self._replan_pending:
            self._replan_pending = False
            if self.scenario.migration and self.active:
                self.shares.recompute(self._contending())
                self._maybe_replan()
        self._drain_queue()
        self.shares.recompute(self._contending())
        self.metrics.observe(self.now,
                             len(self.queue) + len(self.active),
                             self.cluster.num_unavailable)
        self._refresh_pending()
        return True

    def finish(self) -> FleetMetrics:
        """Close the books after the last :meth:`step` and return the
        metrics — the third piece of the start/step/finish loop split
        the ensemble driver composes."""
        if self.recorder is not None:
            # close the books: exact link aggregates and the legacy summary
            # ride in the trace header, so one file is self-contained
            self.link_tracer.finish(self.now)
            self.recorder.meta["links"] = self.link_tracer.snapshot()
            if self.dataplane is not None:
                self.recorder.meta["dataplane"] = self.dataplane.snapshot()
            self.recorder.meta["summary"] = self.metrics.summary()
        return self.metrics

    def run(self) -> FleetMetrics:
        self.start()
        while self.step():
            pass
        return self.finish()


def simulate(scenario: Scenario, policy: RepairPolicy, params: CodeParams,
             seed: int = 0) -> dict:
    """One-call entry point: run and return the metrics summary."""
    return FleetSimulator(scenario, policy, params, seed=seed).run().summary()
