"""Discrete-event fleet simulator: concurrent regenerations over shared links.

The loop advances between events; repairs progress as fluid flows whose
rates are set by the fair-share link model (``sharing.py``).  Exogenous
events (failures, capacity shocks, degraded reads) live on a heap; repair
completions are *derived* each iteration from (remaining work x current
nominal duration), so share changes mid-repair are handled exactly — a
regeneration's duration emerges from contention instead of being read off
its plan.

Per event epoch, every repair that can start (queued slot, >= d healthy
providers, concurrency budget left) is planned in ONE call to the policy
with a stacked tensor of residual-capacity overlays — this is where the
PR-1 batched planning engine runs in throughput mode (many concurrent
repairs per call) rather than Monte-Carlo mode.

Failure model details:

* Poisson failures at ``failure_rate`` per healthy node; the aggregate
  exponential clock is re-drawn whenever the healthy population changes
  (memorylessness makes this exact for the Markov process).
* A failed slot's repair regenerates onto a replacement host in the same
  slot, so the capacity matrix is stable across repairs.
* If an active repair loses a provider to a new failure, it aborts: its
  links are released and the slot is requeued with its original failure
  time (the vulnerability window keeps accruing).  With
  ``Scenario.carryover`` on, the blocks already received from surviving
  providers travel with the queued slot as a per-link bank; re-admission
  keeps the surviving providers and credits the bank against the new
  plan's edge demands, so only the missing flows are re-transferred.
  With it off (default), the work is lost — the pre-PR-3 dynamics,
  bitwise.
* With ``Scenario.migration`` on, every capacity-shock and provider-loss
  epoch offers the in-flight repairs a re-plan through
  ``RepairPolicy.replan`` (one batched call, same engine path as
  admission); a proposal is accepted only if its banked-credited ETA under
  self-excluded shares beats the current one, so migration never extends a
  repair's expected finish at decision time.
* Data-loss accounting: every failure that leaves fewer than k healthy
  slots is a loss event; ``FleetMetrics`` additionally integrates the
  conditional ruin intensity for an MTTDL estimate that works at sane
  failure rates.

Determinism: one root ``seed`` spawns named child streams (capacities,
failures, providers, reads, shocks) via ``np.random.default_rng([seed,
stream])``, and all same-time events have fixed precedence (completions,
then heap order, then the Poisson clock), so a run is bitwise reproducible.
"""
from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import CodeParams

from .cluster import ClusterState
from .events import (CAPACITY_SHOCK, Event, EventQueue, FAILURE,
                     READ_ARRIVAL, READ_DEPARTURE)
from .metrics import FleetMetrics
from .policy import RepairPolicy
from .scenario import Scenario
from .sharing import (ActiveRepair, Link, LinkShareModel, apply_credit,
                      plan_links)

_STREAMS = {"caps": 0, "fail": 1, "prov": 2, "read": 3, "shock": 4}


class QueuedRepair(NamedTuple):
    """A slot awaiting (re-)admission.

    ``bank`` carries blocks already received per physical link when a
    carryover abort requeued the slot (None on a fresh failure);
    ``survivors`` are the aborted plan's still-useful providers, kept at
    re-admission so the banked links actually reappear in the new plan.
    """

    fail_time: float
    node: int
    bank: Optional[Dict[Link, float]] = None
    survivors: Tuple[int, ...] = ()


class FleetSimulator:
    """Simulate ``scenario`` under ``policy`` for one (n, k, d) code."""

    def __init__(self, scenario: Scenario, policy: RepairPolicy,
                 params: CodeParams, seed: int = 0):
        if params.d > scenario.num_nodes - 1:
            raise ValueError(
                f"d={params.d} providers need a cluster of > d nodes, "
                f"got {scenario.num_nodes}")
        self.scenario = scenario
        self.policy = policy
        self.params = params
        self.seed = seed
        self.rng = {name: np.random.default_rng([seed, sid])
                    for name, sid in _STREAMS.items()}

        n = scenario.num_nodes
        base = np.asarray(scenario.capacity_model(self.rng["caps"], n),
                          dtype=np.float64)
        self.cluster = ClusterState(base, rack_size=scenario.rack_size)
        self.caps_base = self.cluster.caps.copy()
        self.shares = LinkShareModel(self.cluster.caps)

        self.now = 0.0
        self.queue: List[QueuedRepair] = []         # fail-time-ordered FIFO
        self.active: List[ActiveRepair] = []        # kept in start order
        self.reads: dict = {}
        self._read_seq = 0
        self._replan_pending = False

        self.events = EventQueue()
        for t, node in sorted(scenario.failures):
            self.events.push(Event(t, FAILURE, (node,)))
        if scenario.shock_period > 0:
            self.events.push(Event(scenario.shock_period, CAPACITY_SHOCK))
        if scenario.read_rate > 0:
            self.events.push(Event(
                float(self.rng["read"].exponential(1.0 / scenario.read_rate)),
                READ_ARRIVAL))
        self.next_fail = self._draw_next_fail()

        self.metrics = FleetMetrics(n=n, k=params.k,
                                    failure_rate=scenario.failure_rate)

    # -- stochastic clocks --------------------------------------------------

    def _draw_next_fail(self) -> float:
        rate = self.scenario.failure_rate * self.cluster.num_healthy
        if rate <= 0:
            return math.inf
        return self.now + float(self.rng["fail"].exponential(1.0 / rate))

    # -- event handlers -----------------------------------------------------

    def _apply_failure(self, node: int) -> bool:
        """Fail ``node``; returns whether the healthy population actually
        changed (False for a redundant injection on an already-down slot,
        in which case the caller must NOT redraw the Poisson clock — a
        no-op redraw would shift the rng stream and break seeded
        comparability between scenarios that differ only in a redundant
        injection)."""
        if self.cluster.state[node] != 0:       # already failed / repairing
            return False
        self.cluster.fail(node)
        if self.cluster.num_healthy < self.params.k:
            self.metrics.on_data_loss()
        self.queue.append(QueuedRepair(self.now, node))
        # tear down degraded reads touching the failed node: their links
        # must not linger as phantom flows until the scheduled departure
        # (the stale READ_DEPARTURE becomes a no-op when it fires)
        dead_reads = [rid for rid, links in self.reads.items()
                      if any(node in link for link, _ in links)]
        for rid in dead_reads:
            self.shares.release(self.reads.pop(rid))
        # abort in-flight repairs that lost a provider
        lost = [i for i, r in enumerate(self.active) if node in r.providers]
        for i in reversed(lost):
            r = self.active.pop(i)
            self.shares.release(r.links)
            self.cluster.abort_repair(r.node)
            if self.scenario.carryover:
                # keep blocks already received — except those parked at the
                # failed provider itself, which died with its host.  Blocks
                # it *sent* have already landed downstream and survive.
                bank = {link: b for link, b in r.banked_now().items()
                        if link[1] != node}
                survivors = tuple(p for p in r.providers if p != node)
                self.queue.append(QueuedRepair(r.fail_time, r.node,
                                               bank, survivors))
                self.metrics.on_abort(carryover=True)
            else:
                self.queue.append(QueuedRepair(r.fail_time, r.node))
                self.metrics.on_abort(carryover=False)
        if lost:
            # requeued aborts carry older fail_times than the failure that
            # evicted them; restore oldest-first admission order (stable on
            # ties, so same-time entries keep insertion order)
            self.queue.sort(key=lambda q: q.fail_time)
            self._replan_pending = True
        # banked blocks sitting *at* the failed node are gone for queued
        # repairs too (the host is replaced before it can relay them on)
        for i, q in enumerate(self.queue):
            if q.bank and any(link[1] == node for link in q.bank):
                self.queue[i] = q._replace(
                    bank={l: b for l, b in q.bank.items() if l[1] != node})
        return True

    def _poisson_failure(self) -> None:
        healthy = self.cluster.healthy_nodes()
        if healthy:
            victim = int(self.rng["fail"].choice(len(healthy)))
            victims = [healthy[victim]]
            sc = self.scenario
            if (sc.rack_size > 0 and sc.rack_burst_prob > 0
                    and self.rng["fail"].random() < sc.rack_burst_prob):
                peers = [p for p in self.cluster.rack_peers(victims[0])
                         if self.cluster.state[p] == 0]
                extra = min(sc.rack_burst_extra, len(peers))
                if extra:
                    idx = self.rng["fail"].choice(len(peers), size=extra,
                                                  replace=False)
                    victims += [peers[int(i)] for i in idx]
            for v in victims:
                self._apply_failure(v)
        self.next_fail = self._draw_next_fail()

    def _capacity_shock(self) -> None:
        sc = self.scenario
        n = sc.num_nodes
        mult = self.rng["shock"].uniform(sc.shock_lo, sc.shock_hi,
                                         size=(n, n))
        self.cluster.caps[:] = self.caps_base * mult
        np.fill_diagonal(self.cluster.caps, 0.0)
        self.events.push(Event(self.now + sc.shock_period, CAPACITY_SHOCK))
        self._replan_pending = True

    def _read_arrival(self) -> None:
        sc = self.scenario
        healthy = self.cluster.healthy_nodes()
        fanin = sc.read_fanin or self.params.k
        if self.cluster.num_unavailable > 0 and len(healthy) > fanin:
            dst_i = int(self.rng["read"].choice(len(healthy)))
            dst = healthy[dst_i]
            pool = [h for h in healthy if h != dst]
            idx = self.rng["read"].choice(len(pool), size=fanin,
                                          replace=False)
            links = [((pool[int(i)], dst), 1.0) for i in idx]
            self.shares.acquire(links)
            rid = self._read_seq
            self._read_seq += 1
            self.reads[rid] = links
            self.events.push(Event(self.now + sc.read_duration,
                                   READ_DEPARTURE, (rid,)))
        self.events.push(Event(
            self.now + float(self.rng["read"].exponential(1.0 / sc.read_rate)),
            READ_ARRIVAL))

    def _read_departure(self, rid: int) -> None:
        links = self.reads.pop(rid, None)
        if links is not None:
            self.shares.release(links)

    # -- repair admission ---------------------------------------------------

    def _pick_providers(self, failed: int, healthy: List[int],
                        survivors: Sequence[int] = ()) -> List[int]:
        """Choose d providers.  ``survivors`` (still-healthy providers of a
        carryover-aborted plan) are kept so the banked links can be
        re-credited, and only the deficit is drawn fresh; with no survivors
        the draw is identical to the pre-carryover uniform sample."""
        if self.scenario.provider_picker is not None:
            return list(self.scenario.provider_picker(failed, healthy,
                                                      self.rng["prov"]))
        alive = self.cluster.healthy_set()
        keep = [s for s in survivors if s in alive][:self.params.d]
        deficit = self.params.d - len(keep)
        if not deficit:
            return keep
        pool = [h for h in healthy if h not in keep]
        idx = self.rng["prov"].choice(len(pool), size=deficit,
                                      replace=False)
        return keep + [pool[int(i)] for i in idx]

    def _drain_queue(self) -> None:
        """Start every currently-startable repair, planned as one batch.

        A repair whose plan comes back with infinite time (it was routed
        over a zero-capacity link) must not start: it would hold its links
        and a ``max_concurrent`` slot forever under static capacities.  It
        is excluded from this epoch's batch and requeued — a later epoch
        (new providers, restored capacity) gets to retry it.  Deferral
        frees the admission slots it held, so the collection loop runs
        again for the rest of the queue; with no dead overlays (the normal
        case) exactly one batched planning call is made per epoch.
        """
        deferred: List[QueuedRepair] = []
        while True:
            startable: List[Tuple[QueuedRepair, List[int]]] = []
            while (self.queue
                   and len(self.active) + len(startable)
                   < self.scenario.max_concurrent):
                healthy = self.cluster.healthy_nodes()
                if len(healthy) < self.params.d:
                    break
                q = self.queue.pop(0)
                self.cluster.start_repair(q.node)
                ids = [q.node] + self._pick_providers(q.node, healthy,
                                                      q.survivors)
                if len(set(ids)) != self.params.d + 1:
                    raise ValueError(
                        f"provider picker returned {ids[1:]} for slot "
                        f"{q.node}: need {self.params.d} distinct providers "
                        f"!= the slot")
                startable.append((q, ids))
            if not startable:
                break
            overlays = np.stack([self.shares.residual_overlay(ids)
                                 for _, ids in startable])
            plans = self.policy.plan_batch(overlays, self.params)
            num_deferred = 0
            for (q, ids), plan in zip(startable, plans):
                if not math.isfinite(plan.time):
                    self.cluster.abort_repair(q.node)   # back to FAILED
                    deferred.append(q)
                    num_deferred += 1
                    continue
                flows = plan_links(plan, ids)
                if q.bank:
                    links, credited, total = apply_credit(flows, q.bank)
                    self.metrics.on_carryover(credited, total)
                    bank = dict(q.bank)
                else:
                    links, bank = flows, {}
                self.shares.acquire(links)
                self.active.append(ActiveRepair(
                    node=q.node, plan=plan, ids=list(ids), links=links,
                    fail_time=q.fail_time, start_time=self.now, bank=bank))
            if not num_deferred:
                break
        if deferred:
            self.queue.extend(deferred)
            self.queue.sort(key=lambda q: q.fail_time)

    # -- in-flight plan migration -------------------------------------------

    def _maybe_replan(self) -> None:
        """Offer every in-flight repair a migration (one batched
        ``policy.replan`` call), accepting a proposal only if its
        banked-credited ETA beats the current one.

        Caller guarantees nominals are fresh (``shares.recompute``).  Each
        proposal is evaluated under self-excluded shares — the repair's own
        occupancy is discounted, so staying on a link costs what it costs
        today and leaving one frees it.  Like admission, proposals are a
        same-epoch snapshot: an accepted migration changes the shares its
        successors are judged under (we recompute between accepts), but the
        overlays the policy planned against are not re-stacked.
        """
        overlays = np.stack([
            self.shares.residual_overlay(
                r.ids, exclude=frozenset(l for l, _ in r.links))
            for r in self.active])
        proposals = self.policy.replan(overlays, self.params)
        for r, plan in zip(list(self.active), proposals):
            if plan is None or not math.isfinite(plan.time):
                continue
            bank = r.banked_now()
            links, credited, total = apply_credit(
                plan_links(plan, r.ids), bank)
            occupied = frozenset(l for l, _ in r.links)
            eta_new = self.shares.admission_time(links, exclude=occupied)
            if eta_new >= r.eta():
                continue
            self.shares.release(r.links)
            r.rebase(plan, links, bank)
            self.shares.acquire(r.links)
            self.metrics.on_migration(credited, total)
            self.shares.recompute(self.active)

    # -- main loop ----------------------------------------------------------

    def _next_completion(self) -> Tuple[float, int]:
        """(absolute time, index into self.active) of the earliest finishing
        repair; on ties the strict < keeps the first hit, and ``active`` is
        in start order, so the earliest-started repair wins."""
        best_t, best_i = math.inf, -1
        for i, r in enumerate(self.active):
            t = self.now + r.eta()
            if t < best_t:
                best_t, best_i = t, i
        return best_t, best_i

    def _advance(self, t: float) -> None:
        dt = t - self.now
        for r in self.active:
            r.advance(dt)
        self.now = t
        self.metrics.observe(t, len(self.queue) + len(self.active),
                             self.cluster.num_unavailable)

    def _complete(self, i: int) -> None:
        r = self.active.pop(i)
        r.remaining = 0.0
        self.shares.release(r.links)
        self.cluster.complete_repair(r.node)
        self.metrics.on_complete(r.fail_time, r.start_time, self.now)
        # the healthy population grew: re-draw the aggregate failure clock
        # (memorylessness makes the re-draw exact, same as on failures)
        self.next_fail = self._draw_next_fail()

    def run(self) -> FleetMetrics:
        end = self.scenario.duration
        self.metrics.observe(0.0, len(self.queue) + len(self.active),
                             self.cluster.num_unavailable)
        self._drain_queue()
        self.shares.recompute(self.active)
        while True:
            t_comp, ci = self._next_completion()
            t_exo = self.events.peek_time()
            t_next = min(t_comp, t_exo, self.next_fail)
            if t_next > end or not math.isfinite(t_next):
                self._advance(end)
                break
            self._advance(t_next)
            # fixed same-time precedence: completion, heap, Poisson clock
            if t_comp <= t_exo and t_comp <= self.next_fail:
                self._complete(ci)
            elif t_exo <= self.next_fail:
                ev = self.events.pop()
                if ev.kind == FAILURE:
                    if self._apply_failure(ev.payload[0]):
                        # redraw only when the healthy population actually
                        # changed; a redundant injection must not shift the
                        # Poisson stream (memorylessness keeps the old draw
                        # exact when the rate is unchanged)
                        self.next_fail = self._draw_next_fail()
                elif ev.kind == CAPACITY_SHOCK:
                    self._capacity_shock()
                elif ev.kind == READ_ARRIVAL:
                    self._read_arrival()
                elif ev.kind == READ_DEPARTURE:
                    self._read_departure(ev.payload[0])
            else:
                self._poisson_failure()
            if self._replan_pending:
                self._replan_pending = False
                if self.scenario.migration and self.active:
                    self.shares.recompute(self.active)
                    self._maybe_replan()
            self._drain_queue()
            self.shares.recompute(self.active)
            self.metrics.observe(self.now,
                                 len(self.queue) + len(self.active),
                                 self.cluster.num_unavailable)
        return self.metrics


def simulate(scenario: Scenario, policy: RepairPolicy, params: CodeParams,
             seed: int = 0) -> dict:
    """One-call entry point: run and return the metrics summary."""
    return FleetSimulator(scenario, policy, params, seed=seed).run().summary()
