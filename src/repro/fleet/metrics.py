"""Fleet-level metrics: what single-repair Monte Carlo cannot measure.

Everything here is accumulated *online* during the event loop so the
summary is O(1) memory in simulated time except the per-repair samples
needed for percentiles.

* backlog — queued + active repairs, integrated time-weighted, plus the
  full step timeline for plotting;
* regeneration time under contention — completion minus start, p50/p99;
* window of vulnerability — per repaired slot, failure to completion (the
  interval the system runs with that slot's redundancy missing), plus the
  fraction of time *any* slot was unavailable;
* MTTDL estimate — the Dimakis et al. (0803.0632) reliability question.
  Counting actual ruin events (> n-k slots down) is hopeless at sane
  failure rates, so alongside the raw count we integrate the conditional
  ruin intensity: while exactly n-k slots are down (one failure from
  loss), the instantaneous loss rate is lambda * healthy(t).  Integrated
  over the run this gives the expected number of loss events, and
  MTTDL ~= duration / E[events] — a standard rare-event estimator that
  stays finite and seeded-deterministic.  The intensity accrues for every
  state at or past the boundary (``unavailable >= n - k``), not just at
  equality — deep-failure excursions keep losing data;
* repair-lifecycle counters (PR 3) — migrations, carryover vs cold aborts,
  and the work-saved fraction (banked blocks credited at re-admissions and
  migrations as a share of the plans' totals);
* plan-vs-reality (ISSUE 6) — the plan-error distribution (realized
  duration of each completed (re)plan segment against the ETA predicted at
  (re)plan time under the *believed* capacities; positive = late), plus
  watchdog counters: repairs flagged lagging, in-place rescue replans,
  straggler evictions, give-ups (retry budget exhausted), degraded-d
  admissions (d' < d helpers), and injected degrade events.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np


# Every monotone counter attribute and the ``summary()`` key it lands
# under — the round-trip contract tests/test_metrics.py pins (a counter
# added without a summary key, or renamed on one side only, fails there).
COUNTER_SUMMARY_KEYS: Dict[str, str] = {
    "completed": "completed",
    "aborted": "aborted",
    "carryover_aborts": "carryover_aborts",
    "cold_aborts": "cold_aborts",
    "migrations": "migrations",
    "work_saved": "work_saved_blocks",
    "data_loss_events": "data_loss_events",
    "watchdog_flags": "watchdog_flags",
    "watchdog_replans": "watchdog_replans",
    "evictions": "evictions",
    "watchdog_giveups": "watchdog_giveups",
    "degraded_admissions": "degraded_admissions",
    "degrade_events": "degrade_events",
    "max_backlog": "max_backlog",
    # coded data plane (ISSUE 10) — these summary keys are emitted only
    # when the dataplane flag is set (any dataplane hook sets it), so the
    # default-path summaries, and with them the fleet golden, are unchanged
    "reads_completed": "reads_completed",
    "reads_dropped": "reads_dropped",
    "reads_torn_down": "reads_torn_down",
    "decode_checks": "decode_checks",
    "decode_failures": "decode_failures",
    "repair_bytes": "repair_bytes",
    "read_bytes": "read_bytes",
}


@dataclasses.dataclass
class FleetMetrics:
    """Online accumulator; call ``observe`` on every state change."""

    n: int
    k: int
    failure_rate: float

    now: float = 0.0
    backlog: int = 0
    unavailable: int = 0

    backlog_integral: float = 0.0
    unavail_time: float = 0.0          # time with >= 1 slot unavailable
    at_risk_time: float = 0.0          # time with exactly n-k slots down
    expected_losses: float = 0.0       # integral of conditional ruin rate
    max_backlog: int = 0

    completed: int = 0
    aborted: int = 0
    carryover_aborts: int = 0          # aborts that kept banked blocks
    cold_aborts: int = 0               # aborts that restarted from zero
    migrations: int = 0                # accepted in-flight plan migrations
    # blocks credited instead of re-sent, summed per (re)plan event: every
    # re-plan would otherwise restart its plan from zero, so a bank that
    # survives several re-plans is (correctly) credited at each of them —
    # this is a per-event demand discount, not a count of unique blocks
    work_saved: float = 0.0
    data_loss_events: int = 0

    # -- plan-vs-reality robustness (ISSUE 6) -------------------------------
    watchdog_flags: int = 0            # repairs flagged lagging/stalled
    watchdog_replans: int = 0          # accepted in-place rescue replans
    evictions: int = 0                 # straggling providers evicted
    watchdog_giveups: int = 0          # retry budget exhausted
    degraded_admissions: int = 0       # repairs admitted with d' < d
    degrade_events: int = 0            # injected + Markov brownouts

    # -- coded data plane (ISSUE 10) ----------------------------------------
    dataplane: bool = False            # gates the dataplane_* summary keys;
    #                                    set by the simulator / any hook below
    reads_completed: int = 0           # fragment-transfer reads delivered
    reads_dropped: int = 0             # trace arrivals with < fanin+1 healthy
    reads_torn_down: int = 0           # in-flight reads killed by a failure
    decode_checks: int = 0             # post-repair can_reconstruct checks
    decode_failures: int = 0           # checks where k nodes could NOT decode
    repair_bytes: float = 0.0          # coded repair bytes on the wire
    read_bytes: float = 0.0            # fragment read bytes on the wire

    plan_errors: List[float] = dataclasses.field(default_factory=list)
    credit_fractions: List[float] = dataclasses.field(default_factory=list)
    regen_times: List[float] = dataclasses.field(default_factory=list)
    vulnerability_windows: List[float] = dataclasses.field(
        default_factory=list)
    wait_times: List[float] = dataclasses.field(default_factory=list)
    read_latencies: List[float] = dataclasses.field(default_factory=list)
    backlog_timeline: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)

    def observe(self, t: float, backlog: int, unavailable: int) -> None:
        """Advance the clock to ``t`` integrating the previous state, then
        record the new (backlog, unavailable) levels."""
        dt = t - self.now
        if dt < 0:
            raise ValueError(f"time ran backwards: {self.now} -> {t}")
        if dt > 0:
            self.backlog_integral += self.backlog * dt
            if self.unavailable > 0:
                self.unavail_time += dt
            if self.unavailable == self.n - self.k:
                self.at_risk_time += dt
            if self.unavailable >= self.n - self.k:
                # conditional ruin intensity: every further failure is a
                # loss event, *including* while already past the boundary —
                # integrating only at equality would stop accruing when a
                # run dips deeper and bias the MTTDL estimate high
                healthy = self.n - self.unavailable
                self.expected_losses += self.failure_rate * healthy * dt
        self.now = t
        if backlog != self.backlog or not self.backlog_timeline:
            self.backlog_timeline.append((t, backlog))
        self.backlog = backlog
        self.unavailable = unavailable
        self.max_backlog = max(self.max_backlog, backlog)

    def on_complete(self, fail_time: float, start_time: float,
                    end_time: float, plan_t0: Optional[float] = None,
                    predicted: Optional[float] = None) -> None:
        self.completed += 1
        self.regen_times.append(end_time - start_time)
        self.wait_times.append(start_time - fail_time)
        self.vulnerability_windows.append(end_time - fail_time)
        # plan error: the realized duration of the final (re)plan segment
        # against its believed-capacity prediction — relative, so 0 means
        # the plan's map matched the territory and +1 means it took twice
        # as long as predicted
        if (plan_t0 is not None and predicted is not None
                and math.isfinite(predicted) and predicted > 0):
            self.plan_errors.append((end_time - plan_t0) / predicted - 1.0)

    def on_watchdog_flag(self) -> None:
        self.watchdog_flags += 1

    def on_watchdog_replan(self, saved: float, planned: float) -> None:
        """A lagging repair was rescued in place by a credited replan."""
        self.watchdog_replans += 1
        self.on_carryover(saved, planned)

    def on_eviction(self) -> None:
        self.evictions += 1

    def on_watchdog_giveup(self) -> None:
        self.watchdog_giveups += 1

    def on_degraded_admission(self) -> None:
        self.degraded_admissions += 1

    def on_degrade(self) -> None:
        self.degrade_events += 1

    def on_abort(self, carryover: bool = False) -> None:
        self.aborted += 1
        if carryover:
            self.carryover_aborts += 1
        else:
            self.cold_aborts += 1

    def on_carryover(self, saved: float, planned: float) -> None:
        """Banked-work credit applied at a (re)plan event: ``saved`` of the
        plan's ``planned`` total blocks were already received and are not
        re-sent (see the ``work_saved`` field note on summing)."""
        self.work_saved += saved
        self.credit_fractions.append(saved / planned if planned > 0 else 0.0)

    def on_migration(self, saved: float, planned: float) -> None:
        """An in-flight repair migrated to a new plan, with credit."""
        self.migrations += 1
        self.on_carryover(saved, planned)

    def on_data_loss(self) -> None:
        self.data_loss_events += 1

    # -- coded data plane (ISSUE 10) ----------------------------------------

    def on_read_complete(self, latency: float, nbytes: float) -> None:
        """A fragment-transfer read delivered all its bytes."""
        self.dataplane = True
        self.reads_completed += 1
        self.read_latencies.append(latency)
        self.read_bytes += nbytes

    def on_read_drop(self) -> None:
        """A trace arrival found fewer than fanin + 1 healthy nodes."""
        self.dataplane = True
        self.reads_dropped += 1

    def on_read_teardown(self, nbytes: float) -> None:
        """A failure killed an in-flight read; ``nbytes`` already crossed
        the wire and still count as read traffic."""
        self.dataplane = True
        self.reads_torn_down += 1
        self.read_bytes += nbytes

    def on_repair_bytes(self, nbytes: float) -> None:
        """A repair segment ended, having moved ``nbytes`` of coded blocks."""
        self.dataplane = True
        self.repair_bytes += nbytes

    def on_decode_check(self, ok: bool) -> None:
        """Post-repair decode verification via ``rlnc.can_reconstruct``."""
        self.dataplane = True
        self.decode_checks += 1
        if not ok:
            self.decode_failures += 1

    # -- summary ------------------------------------------------------------

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def summary(self) -> Dict[str, float]:
        dur = max(self.now, 1e-300)
        mttdl = (dur / self.expected_losses
                 if self.expected_losses > 0 else math.inf)
        out = {
            "duration": self.now,
            "completed": self.completed,
            "aborted": self.aborted,
            "carryover_aborts": self.carryover_aborts,
            "cold_aborts": self.cold_aborts,
            "migrations": self.migrations,
            "work_saved_blocks": self.work_saved,
            "work_saved_fraction": (float(np.mean(self.credit_fractions))
                                    if self.credit_fractions else 0.0),
            "mean_backlog": self.backlog_integral / dur,
            "max_backlog": self.max_backlog,
            "regen_p50": self._pct(self.regen_times, 50),
            "regen_p99": self._pct(self.regen_times, 99),
            "regen_mean": (float(np.mean(self.regen_times))
                           if self.regen_times else 0.0),
            "wait_p99": self._pct(self.wait_times, 99),
            "vulnerability_p99": self._pct(self.vulnerability_windows, 99),
            "unavail_fraction": self.unavail_time / dur,
            "at_risk_fraction": self.at_risk_time / dur,
            "data_loss_events": self.data_loss_events,
            "expected_data_losses": self.expected_losses,
            "mttdl_estimate": mttdl,
            "watchdog_flags": self.watchdog_flags,
            "watchdog_replans": self.watchdog_replans,
            "evictions": self.evictions,
            "watchdog_giveups": self.watchdog_giveups,
            "degraded_admissions": self.degraded_admissions,
            "degrade_events": self.degrade_events,
            "plan_err_mean": (float(np.mean(self.plan_errors))
                              if self.plan_errors else 0.0),
            "plan_err_p50": self._pct(self.plan_errors, 50),
            "plan_err_p99": self._pct(self.plan_errors, 99),
        }
        if self.dataplane:
            out.update({
                "reads_completed": self.reads_completed,
                "reads_dropped": self.reads_dropped,
                "reads_torn_down": self.reads_torn_down,
                "decode_checks": self.decode_checks,
                "decode_failures": self.decode_failures,
                "repair_bytes": self.repair_bytes,
                "read_bytes": self.read_bytes,
                "read_p50": self._pct(self.read_latencies, 50),
                "read_p99": self._pct(self.read_latencies, 99),
                "read_mean": (float(np.mean(self.read_latencies))
                              if self.read_latencies else 0.0),
            })
        return out
