"""Random linear network coding data plane (paper Section II-A).

A file of M blocks a_1..a_M is encoded into n*alpha coded blocks b_i =
sum_j c_ij a_j and spread over n nodes (alpha blocks each).  Every coded
block carries its length-M coding vector.  Regeneration, relaying and
reconstruction are all GF matrix multiplications on (coding-vector, payload)
pairs — the compute hot-spot accelerated by ``repro.kernels.gf_matmul``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .gf import GF, GF8


@dataclasses.dataclass
class CodedBlocks:
    """A batch of coded blocks: coding vectors (num, M) + payload (num, B)."""

    vectors: np.ndarray   # (num, M) over GF
    payload: np.ndarray   # (num, block_bytes) over GF

    def __post_init__(self):
        assert self.vectors.shape[0] == self.payload.shape[0]

    @property
    def num(self) -> int:
        return self.vectors.shape[0]

    def concat(self, other: "CodedBlocks") -> "CodedBlocks":
        return CodedBlocks(np.concatenate([self.vectors, other.vectors]),
                           np.concatenate([self.payload, other.payload]))


class RLNC:
    """Stateless coding operations over a chosen field."""

    def __init__(self, field: GF = GF8, matmul=None):
        self.field = field
        # pluggable GF matmul (e.g. the Pallas kernel wrapper); defaults to
        # the table-based numpy path.
        self._matmul = matmul if matmul is not None else field.matmul

    # -- file distribution ---------------------------------------------------

    def distribute(self, file_blocks: np.ndarray, n: int, alpha: int,
                   rng: np.random.Generator) -> List[CodedBlocks]:
        """Encode M file blocks into n nodes * alpha coded blocks (random
        linear code; MDS with probability -> 1 for large fields)."""
        M = file_blocks.shape[0]
        C = self.field.random((n * alpha, M), rng)
        payload = self._matmul(C, file_blocks)
        return [CodedBlocks(C[i * alpha:(i + 1) * alpha],
                            payload[i * alpha:(i + 1) * alpha])
                for i in range(n)]

    # -- regeneration --------------------------------------------------------

    def encode(self, local: CodedBlocks, num_out: int,
               rng: np.random.Generator) -> CodedBlocks:
        """Provider-side: num_out random combinations of the local blocks."""
        R = self.field.random((num_out, local.num), rng)
        return CodedBlocks(self._matmul(R, local.vectors),
                           self._matmul(R, local.payload))

    def relay(self, received: CodedBlocks, own: CodedBlocks, num_out: int,
              rng: np.random.Generator) -> CodedBlocks:
        """Interior tree node: re-encode (received ++ freshly generated own
        data) down to num_out blocks (Section V-A)."""
        pool = received.concat(own)
        R = self.field.random((num_out, pool.num), rng)
        return CodedBlocks(self._matmul(R, pool.vectors),
                           self._matmul(R, pool.payload))

    def regenerate(self, received: CodedBlocks, alpha: int,
                   rng: np.random.Generator) -> CodedBlocks:
        """Newcomer: store alpha random combinations of everything received."""
        R = self.field.random((alpha, received.num), rng)
        return CodedBlocks(self._matmul(R, received.vectors),
                           self._matmul(R, received.payload))

    # -- reconstruction --------------------------------------------------------

    def can_reconstruct(self, nodes: Sequence[CodedBlocks], M: int) -> bool:
        V = np.concatenate([nd.vectors for nd in nodes])
        return self.field.rank(V) >= M

    def reconstruct(self, nodes: Sequence[CodedBlocks], M: int) -> np.ndarray:
        """Recover the original M file blocks from >= M independent coded
        blocks (MDS reconstruction, Section II-A)."""
        V = np.concatenate([nd.vectors for nd in nodes])
        P = np.concatenate([nd.payload for nd in nodes])
        # pick M independent rows
        idx, r = [], 0
        work = np.array(V, dtype=np.int64, copy=True)
        picked = np.zeros((0, V.shape[1]), dtype=np.int64)
        for i in range(V.shape[0]):
            cand = np.concatenate([picked, work[i:i + 1]])
            if self.field.rank(cand) > r:
                picked, r = cand, r + 1
                idx.append(i)
                if r == M:
                    break
        if r < M:
            raise ValueError(f"rank {r} < M={M}: cannot reconstruct")
        A = V[idx]
        Y = P[idx]
        return self.field.solve(A, Y)
