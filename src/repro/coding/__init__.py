"""GF(2^w) arithmetic and random linear network coding (paper Section II)."""
from .gf import GF, GF8, GF16, GF8_POLY, GF16_POLY
from .rlnc import CodedBlocks, RLNC

__all__ = ["GF", "GF8", "GF16", "GF8_POLY", "GF16_POLY", "CodedBlocks", "RLNC"]
