"""Finite-field arithmetic for the regenerating code (GF(2^8) and GF(2^16)).

GF(2^8) uses the standard storage-systems polynomial x^8+x^4+x^3+x^2+1
(0x11D) with generator 2; GF(2^16) uses 0x1100B.  The numpy paths are
table-based (host-side planning/decoding); the jnp path in
``repro.kernels.ref``/``gf_matmul`` uses a bit-plane decomposition that maps
onto the TPU MXU (see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

GF8_POLY = 0x11D
GF16_POLY = 0x1100B


@functools.lru_cache(maxsize=None)
def _tables(bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """(EXP, LOG) tables.  EXP has 2*(q-1) entries to skip the mod."""
    poly = GF8_POLY if bits == 8 else GF16_POLY
    q = 1 << bits
    exp = np.zeros(2 * (q - 1), dtype=np.int64)
    log = np.zeros(q, dtype=np.int64)
    x = 1
    for i in range(q - 1):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & q:
            x ^= poly
    exp[q - 1:] = exp[: q - 1]
    return exp, log


class GF:
    """Galois field GF(2^bits), bits in {8, 16}; numpy vectorized."""

    def __init__(self, bits: int = 8):
        if bits not in (8, 16):
            raise ValueError("bits must be 8 or 16")
        self.bits = bits
        self.q = 1 << bits
        self.poly = GF8_POLY if bits == 8 else GF16_POLY
        self.exp, self.log = _tables(bits)
        self.dtype = np.uint8 if bits == 8 else np.uint16
        # Narrow tables for the blocked matmul.  mul_log[0] points past the
        # live EXP region, where mul_exp is zero — so products with a zero
        # operand come out 0 straight from the gather, with no mask pass.
        # Narrow dtypes keep the (m, bk, n) product block cache-resident.
        q1 = self.q - 1
        self.mul_exp = np.zeros(4 * q1 + 1, dtype=self.dtype)
        self.mul_exp[:2 * q1] = self.exp.astype(self.dtype)
        self.mul_log = self.log.astype(np.int32)
        self.mul_log[0] = 2 * q1

    # -- scalar/elementwise ------------------------------------------------

    def mul(self, a, b):
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        out = self.exp[self.log[a] + self.log[b]]
        return np.where((a == 0) | (b == 0), 0, out).astype(self.dtype)

    def inv(self, a):
        a = np.asarray(a, dtype=np.int64)
        if np.any(a == 0):
            raise ZeroDivisionError("inverse of 0 in GF")
        return self.exp[(self.q - 1) - self.log[a]].astype(self.dtype)

    def div(self, a, b):
        return self.mul(a, self.inv(b))

    def pow(self, a, e: int):
        a = np.asarray(a, dtype=np.int64)
        if e == 0:
            return np.ones_like(a, dtype=self.dtype)
        la = self.log[a] * (e % (self.q - 1))
        out = self.exp[la % (self.q - 1)]
        return np.where(a == 0, 0, out).astype(self.dtype)

    # -- linear algebra ----------------------------------------------------

    def matmul(self, A: np.ndarray, B: np.ndarray,
               block_k: int | None = None) -> np.ndarray:
        """C = A @ B over GF (XOR-accumulate of field products).

        Blocked table-lookup formulation: a whole K-chunk of outer products
        is gathered from the narrow EXP table as one (m, bk, n) lookup and
        folded with a single XOR reduction, instead of one Python-level
        iteration per K column (``matmul_rowloop``, kept as the reference
        oracle).  ``block_k`` keeps the field-dtype temporary inside ~2 MB
        (cache-resident) by default.
        """
        A = np.asarray(A, dtype=np.int64)
        B = np.asarray(B, dtype=np.int64)
        assert A.ndim == 2 and B.ndim == 2 and A.shape[1] == B.shape[0]
        m, K = A.shape
        n = B.shape[1]
        if block_k is None:
            # working set per block: the int32 index intermediate (4 B/elem)
            # plus the field-dtype product — size both into ~2 MB
            elem_bytes = 4 + self.dtype().itemsize
            block_k = max(1, min(K, (1 << 21) // max(1, m * n * elem_bytes)))
        logA = self.mul_log[A]
        logB = self.mul_log[B]
        out = np.zeros((m, n), dtype=self.dtype)
        for k0 in range(0, K, block_k):
            k1 = min(k0 + block_k, K)
            out ^= np.bitwise_xor.reduce(
                self.mul_exp[logA[:, k0:k1, None] + logB[None, k0:k1, :]],
                axis=1)
        return out

    def matmul_rowloop(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        """Reference oracle: per-column XOR-accumulate (the pre-blocking
        implementation; benchmarked against ``matmul`` in benchmarks/kernel_gf)."""
        A = np.asarray(A, dtype=np.int64)
        B = np.asarray(B, dtype=np.int64)
        assert A.ndim == 2 and B.ndim == 2 and A.shape[1] == B.shape[0]
        logA = self.log[A]
        logB = self.log[B]
        out = np.zeros((A.shape[0], B.shape[1]), dtype=np.int64)
        for k in range(A.shape[1]):
            prod = self.exp[logA[:, k][:, None] + logB[k][None, :]]
            prod = np.where((A[:, k][:, None] == 0) | (B[k][None, :] == 0), 0, prod)
            out ^= prod
        return out.astype(self.dtype)

    def rank(self, A: np.ndarray) -> int:
        """Rank over GF via Gaussian elimination."""
        A = np.array(A, dtype=np.int64, copy=True)
        rows, cols = A.shape
        r = 0
        for c in range(cols):
            piv = None
            for i in range(r, rows):
                if A[i, c]:
                    piv = i
                    break
            if piv is None:
                continue
            A[[r, piv]] = A[[piv, r]]
            inv = int(self.inv(A[r, c]))
            A[r] = self.mul(A[r], inv)
            mask = A[:, c] != 0
            mask[r] = False
            if mask.any():
                A[mask] ^= self.mul(A[mask, c][:, None], A[r][None, :]).astype(np.int64)
            r += 1
            if r == rows:
                break
        return r

    def solve(self, A: np.ndarray, Y: np.ndarray) -> np.ndarray:
        """Solve A X = Y over GF (A square, invertible)."""
        A = np.array(A, dtype=np.int64, copy=True)
        Y = np.array(Y, dtype=np.int64, copy=True)
        n = A.shape[0]
        assert A.shape == (n, n) and Y.shape[0] == n
        for c in range(n):
            piv = None
            for i in range(c, n):
                if A[i, c]:
                    piv = i
                    break
            if piv is None:
                raise np.linalg.LinAlgError("singular GF matrix")
            A[[c, piv]] = A[[piv, c]]
            Y[[c, piv]] = Y[[piv, c]]
            inv = int(self.inv(A[c, c]))
            A[c] = self.mul(A[c], inv)
            Y[c] = self.mul(Y[c], inv)
            mask = A[:, c] != 0
            mask[c] = False
            if mask.any():
                f = A[mask, c][:, None]
                A[mask] ^= self.mul(f, A[c][None, :]).astype(np.int64)
                Y[mask] ^= self.mul(f, Y[c][None, :]).astype(np.int64)
        return Y.astype(self.dtype)

    def inv_matrix(self, A: np.ndarray) -> np.ndarray:
        n = A.shape[0]
        return self.solve(A, np.eye(n, dtype=np.int64))

    # -- structured generators ----------------------------------------------

    def cauchy_matrix(self, rows: int, cols: int) -> np.ndarray:
        """Cauchy matrix: every square submatrix is nonsingular (true MDS).
        Requires rows + cols <= q."""
        if rows + cols > self.q:
            raise ValueError(f"Cauchy needs rows+cols <= {self.q}")
        x = np.arange(rows, dtype=np.int64)
        y = np.arange(rows, rows + cols, dtype=np.int64)
        return self.inv((x[:, None] ^ y[None, :]).astype(np.int64)).astype(self.dtype)

    def random(self, shape, rng: np.random.Generator) -> np.ndarray:
        return rng.integers(0, self.q, size=shape, dtype=np.uint32).astype(self.dtype)


GF8 = GF(8)
GF16 = GF(16)
