"""Repair-round simulation (paper Section VI + Appendix A Fig. 10).

Two levels of fidelity:

* ``compare_schemes`` — planning-level Monte Carlo: per round, sample an
  overlay, plan with each scheme, record regeneration time and total repair
  traffic normalized against STAR on the *same* network (Figs 6-8).
* ``RlncSimulator`` — data-plane simulation with real GF coding vectors:
  executes plans block-by-block (provider encode, interior relay, newcomer
  regenerate) and measures the probability that k random nodes can still
  reconstruct the file (Fig. 10, RCTREE's MDS collapse).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.coding import GF, GF8, RLNC, CodedBlocks
from repro.core import (CodeParams, RepairPlan, caps_tensor, get_scheme,
                        plan, plan_many, plans_from_batch)
from .capacities import CapSampler


# ---------------------------------------------------------------------------
# Planning-level Monte Carlo (Figs 6-8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchemeStats:
    scheme: str
    mean_time: float
    mean_norm_time: float      # vs STAR on the same sampled network
    mean_traffic: float
    mean_norm_traffic: float
    plan_seconds: float        # mean planner wall time
    engine: str = "scalar"     # engine that actually planned this scheme


def compare_schemes(params: CodeParams, sampler: CapSampler,
                    schemes: Sequence[str], trials: int,
                    seed: int = 0, engine: str = "batched",
                    witness: str = "exact",
                    ) -> Dict[str, SchemeStats]:
    """Monte-Carlo scheme comparison over ``trials`` sampled overlays.

    All planning is dispatched through :func:`repro.core.plan_many` /
    :func:`repro.core.plan`, so engine selection, per-scheme kwarg
    forwarding (``witness`` reaches exactly the schemes that declared it)
    and the scalar fallback for registry entries without a batched planner
    (rctree) are owned by the scheme registry — the fallback warns once per
    scheme per process and is surfaced in ``SchemeStats.engine``.
    ``engine="batched"`` (default) plans every trial at once;
    ``engine="jax"`` routes jax-capable schemes through the jit tier
    (others fall back per the registry, with its once-per-scheme warning)
    while the STAR normalization baseline stays on the batched engine so
    normalized metrics are engine-for-engine comparable;
    ``engine="scalar"`` is the original per-network loop, kept as the
    correctness oracle (see tests/test_batched.py).  ``witness`` selects
    the traffic-minimal witness engine for fr/ftr: the exact level-cut
    oracle (default) or the per-trial scipy LP (``witness="lp"``).
    """
    import time as _time

    if engine not in ("batched", "scalar", "jax"):
        raise ValueError(f"unknown engine {engine!r}")
    rng = random.Random(seed)
    nets = [sampler(rng, params.d) for _ in range(trials)]

    if engine in ("batched", "jax"):
        caps = caps_tensor(nets)
        base = plan_many(caps, params, "star", engine="batched")
        out: Dict[str, SchemeStats] = {}
        for s in schemes:
            t0 = _time.perf_counter()
            res = plan_many(caps, params, s, engine=engine,
                            witness=witness)
            dt = _time.perf_counter() - t0
            out[s] = SchemeStats(
                s, float(res.times.mean()),
                float((res.times / base.times).mean()),
                float(res.traffic.mean()),
                float((res.traffic / base.traffic).mean()), dt / trials,
                engine=res.engine)
        return out

    acc = {s: [0.0, 0.0, 0.0, 0.0, 0.0] for s in schemes}
    for net in nets:
        base = plan(net, params, "star", engine="scalar")
        for s in schemes:
            t0 = _time.perf_counter()
            p = plan(net, params, s, engine="scalar", witness=witness)
            dt = _time.perf_counter() - t0
            a = acc[s]
            a[0] += p.time
            a[1] += p.time / base.time
            a[2] += p.total_traffic
            a[3] += p.total_traffic / base.total_traffic
            a[4] += dt
    return {
        s: SchemeStats(s, a[0] / trials, a[1] / trials, a[2] / trials,
                       a[3] / trials, a[4] / trials)
        for s, a in acc.items()
    }


# ---------------------------------------------------------------------------
# Data-plane simulation with real coding vectors (Fig. 10)
# ---------------------------------------------------------------------------

class RlncSimulator:
    """Distributed storage system with actual RLNC state per node."""

    def __init__(self, params: CodeParams, field: GF = GF8,
                 block_bytes: int = 4, seed: int = 0,
                 matmul: Optional[Callable] = None, engine: str = "batched"):
        if abs(params.M - round(params.M)) > 1e-9 or \
           abs(params.alpha - round(params.alpha)) > 1e-9:
            raise ValueError("data-plane simulation needs integral M, alpha")
        if engine not in ("batched", "scalar"):
            raise ValueError(f"unknown engine {engine!r}")
        self.params = params
        self.engine = engine
        self.field = field
        self.rl = RLNC(field, matmul=matmul)
        self.np_rng = np.random.default_rng(seed)
        self.rng = random.Random(seed + 1)
        M, n, alpha = int(params.M), params.n, int(round(params.alpha))
        self.file_blocks = field.random((M, block_bytes), self.np_rng)
        self.nodes: Dict[int, CodedBlocks] = dict(
            enumerate(self.rl.distribute(self.file_blocks, n, alpha,
                                         self.np_rng)))

    def execute_plan(self, plan: RepairPlan, failed: int,
                     provider_ids: Sequence[int]) -> None:
        """Replace ``failed`` by running ``plan`` on the real coded state.

        Fractional betas/flows are ceil-rounded (Section III-C).  For the
        broken RCTREE baseline, flows are the plan's fixed per-edge beta,
        which is what destroys information at interior nodes.
        """
        alpha = int(round(self.params.alpha))
        idmap = {i: pid for i, pid in enumerate(provider_ids, start=1)}
        children: Dict[int, List[int]] = {}
        for u, p in plan.parent.items():
            children.setdefault(p, []).append(u)

        def produce(u: int) -> CodedBlocks:
            """Blocks node u sends to its tree parent."""
            own_quota = plan.betas[u - 1]
            recv: Optional[CodedBlocks] = None
            for ch in children.get(u, []):
                part = produce(ch)
                recv = part if recv is None else recv.concat(part)
            send_quota = int(math.ceil(plan.flows[(u, plan.parent[u])] - 1e-9))
            own = self.rl.encode(self.nodes[idmap[u]],
                                 int(math.ceil(own_quota - 1e-9)), self.np_rng)
            if recv is None:
                out = own
            else:
                pool = recv.concat(own)
                if pool.num > send_quota:
                    out = self.rl.relay(recv, own, send_quota, self.np_rng)
                else:
                    out = pool
            # cap at the plan's edge flow (RCTREE keeps this below alpha)
            if out.num > send_quota:
                out = CodedBlocks(out.vectors[:send_quota],
                                  out.payload[:send_quota])
            return out

        received: Optional[CodedBlocks] = None
        for r in children.get(0, []):
            part = produce(r)
            received = part if received is None else received.concat(part)
        assert received is not None
        self.nodes[failed] = self.rl.regenerate(received, alpha, self.np_rng)

    def _sample_round(self, sampler: CapSampler,
                      failed: Optional[int] = None):
        """(failed, providers, overlay) for one repair round.

        Draws only from ``self.rng`` — the data-plane ``np_rng`` is a
        separate stream, so rounds may be pre-sampled in bulk (for batched
        planning) without perturbing execution randomness.  Anything else
        drawing from ``self.rng`` between rounds (subset-sampled
        ``reconstruction_probability``) IS perturbed by bulk pre-sampling;
        see ``reconstruction_vs_rounds``."""
        ids = sorted(self.nodes)
        if failed is None:
            failed = self.rng.choice(ids)
        survivors = [i for i in ids if i != failed]
        providers = self.rng.sample(survivors, self.params.d)
        net = sampler(self.rng, self.params.d)
        return failed, providers, net

    def plan_rounds(self, scheme: str, sampler: CapSampler,
                    rounds: int) -> List:
        """Pre-sample ``rounds`` repair rounds and plan them all.

        With the batched engine this is ONE ``plan_batch`` call for the
        whole trial (plans depend only on the sampled overlays, never on
        the coded state); schemes without a batched planner (rctree) use
        the scalar loop.  Returns [(failed, providers, plan), ...] ready
        for ``execute_plan``.
        """
        drawn = [self._sample_round(sampler) for _ in range(rounds)]
        # engine="auto" rides the batched planner when the registry has one
        # and silently takes the scalar oracle otherwise (rctree)
        eng = "auto" if self.engine == "batched" else "scalar"
        res = plan_many([net for _, _, net in drawn], self.params, scheme,
                        engine=eng)
        plans = plans_from_batch(res, self.params)
        return [(f, p, pl) for (f, p, _), pl in zip(drawn, plans)]

    def repair_round(self, scheme: str, sampler: CapSampler,
                     failed: Optional[int] = None) -> RepairPlan:
        failed, providers, net = self._sample_round(sampler, failed)
        eng = "auto" if self.engine == "batched" else "scalar"
        pl = plans_from_batch(plan_many([net], self.params, scheme,
                                        engine=eng), self.params)[0]
        self.execute_plan(pl, failed, providers)
        return pl

    def reconstruction_probability(self, samples: int = 0) -> float:
        """Fraction of k-subsets (all, or ``samples`` random ones) whose
        combined coding vectors have rank >= M."""
        ids = sorted(self.nodes)
        k, M = self.params.k, int(self.params.M)
        combos = list(itertools.combinations(ids, k))
        if samples and samples < len(combos):
            combos = self.rng.sample(combos, samples)
        ok = 0
        for combo in combos:
            if self.rl.can_reconstruct([self.nodes[i] for i in combo], M):
                ok += 1
        return ok / len(combos)


def reconstruction_vs_rounds(params: CodeParams, scheme: str,
                             sampler: CapSampler, rounds: int, trials: int,
                             field: GF = GF8, seed: int = 0,
                             subset_samples: int = 0,
                             engine: str = "batched") -> List[float]:
    """Fig. 10: mean reconstruction probability after each repair round.

    Planning runs on the batched engine by default: each trial's rounds are
    pre-sampled and planned in ONE ``plan_batch`` call (the plan depends
    only on the sampled overlay, never on the coded state, and the overlay
    rng is a separate stream from the data-plane rng — so the round-by-round
    scalar oracle, ``engine="scalar"``, produces identical node states).

    The bulk path requires that nothing else consumes ``sim.rng`` between
    rounds: with ``subset_samples > 0``, ``reconstruction_probability``
    draws k-subsets from that same stream, so bulk pre-sampling would
    reorder the draws and diverge from the oracle — those calls (and
    schemes without a batched planner, e.g. rctree) use the round-by-round
    loop instead, which preserves the stream order exactly."""
    probs = [0.0] * (rounds + 1)
    for tr in range(trials):
        sim = RlncSimulator(params, field=field, seed=seed + 1000 * tr,
                            engine=engine)
        probs[0] += sim.reconstruction_probability(subset_samples)
        if (engine == "batched" and subset_samples == 0
                and get_scheme(scheme).batched is not None):
            planned = sim.plan_rounds(scheme, sampler, rounds)
            for r, (failed, providers, plan) in enumerate(planned, start=1):
                sim.execute_plan(plan, failed, providers)
                probs[r] += sim.reconstruction_probability(subset_samples)
        else:
            for r in range(1, rounds + 1):
                sim.repair_round(scheme, sampler)
                probs[r] += sim.reconstruction_probability(subset_samples)
    return [p / trials for p in probs]
