"""Link-capacity models for overlay networks and whole clusters.

``uniform`` reproduces the paper's evaluation setting (PlanetLab-derived
U[10,120] Mbps, Section VI) for a single repair's (d+1)-node overlay; the
TPU-fleet model lives in ``repro.ft.topology`` (deployment adaptation,
DESIGN.md §3).

``uniform_matrix`` is the cluster-scale analogue used by the fleet
simulator (``repro.fleet``): it samples the full n x n directed capacity
matrix once, so concurrent repairs planned at different times see the
*same* physical link and contend on it — the property per-repair overlay
sampling cannot express.
"""
from __future__ import annotations

import random
from typing import Callable, List

import numpy as np

from repro.core import OverlayNetwork

CapSampler = Callable[[random.Random, int], OverlayNetwork]

# (numpy Generator, cluster size n) -> (n, n) directed capacities, diag 0
ClusterCapSampler = Callable[[np.random.Generator, int], np.ndarray]


def uniform_matrix(lo: float = 10.0, hi: float = 120.0) -> ClusterCapSampler:
    """All n*(n-1) directed cluster links i.i.d. U[lo, hi] (blocks/sec)."""

    def sample(rng: np.random.Generator, n: int) -> np.ndarray:
        caps = rng.uniform(lo, hi, size=(n, n))
        np.fill_diagonal(caps, 0.0)
        return caps

    return sample


def uniform(lo: float = 10.0, hi: float = 120.0) -> CapSampler:
    """All directed links i.i.d. U[lo, hi] (Mbps) — the paper's default."""

    def sample(rng: random.Random, d: int) -> OverlayNetwork:
        cap: List[List[float]] = [[0.0] * (d + 1) for _ in range(d + 1)]
        for u in range(d + 1):
            for v in range(d + 1):
                if u != v:
                    cap[u][v] = rng.uniform(lo, hi)
        return OverlayNetwork(cap)

    return sample


# the five distributions of Fig. 7
FIG7_DISTRIBUTIONS = {
    "U1[0.3,120]": uniform(0.3, 120.0),
    "U2[3,120]": uniform(3.0, 120.0),
    "U3[30,120]": uniform(30.0, 120.0),
    "U4[60,120]": uniform(60.0, 120.0),
    "U5[90,120]": uniform(90.0, 120.0),
}
