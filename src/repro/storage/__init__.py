"""Simulated distributed storage cluster (paper evaluation substrate)."""
from .capacities import (CapSampler, ClusterCapSampler, FIG7_DISTRIBUTIONS,
                         uniform, uniform_matrix)
from .simulator import (RlncSimulator, SchemeStats, compare_schemes,
                        reconstruction_vs_rounds)

__all__ = ["CapSampler", "ClusterCapSampler", "FIG7_DISTRIBUTIONS",
           "uniform", "uniform_matrix", "RlncSimulator", "SchemeStats",
           "compare_schemes", "reconstruction_vs_rounds"]
