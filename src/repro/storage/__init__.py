"""Simulated distributed storage cluster (paper evaluation substrate)."""
from .capacities import CapSampler, FIG7_DISTRIBUTIONS, uniform
from .simulator import (RlncSimulator, SchemeStats, compare_schemes,
                        reconstruction_vs_rounds)

__all__ = ["CapSampler", "FIG7_DISTRIBUTIONS", "uniform", "RlncSimulator",
           "SchemeStats", "compare_schemes", "reconstruction_vs_rounds"]
