"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm: the sequence is split into chunks of Q steps; within a
chunk the output is a masked quadratic form (attention-like, MXU friendly),
across chunks a small (H, P, N) state is carried by a scan — O(L) total
work and memory, which is what qualifies ssm/hybrid archs for the
``long_500k`` shape.

Recurrence (per head h, state S in R^{P x N}):
    S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t B_t^T,      y_t = S_t C_t + D_h x_t
``ssd_reference`` implements it step-by-step (oracle for tests);
``apply_ssd`` is the chunked equivalent; ``ssd_step`` is the O(1) decode
update.  B/C use a single group shared across heads (mamba2 default
ngroups=1).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dt
from repro.distributed.hints import BATCH, hint

_NEG = -1e9


def init_ssd(cfg: ModelConfig, key) -> Params:
    d, di, N, Hs, conv = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                          cfg.ssm_heads, cfg.ssm_conv)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    proj_out = 2 * di + 2 * N + Hs  # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * s).astype(dt(cfg, "param")),
        "conv_w": (jax.random.normal(ks[1], (conv, di + 2 * N)) * 0.5).astype(dt(cfg, "param")),
        "conv_b": jnp.zeros((di + 2 * N,), dt(cfg, "param")),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, Hs)).astype(jnp.float32),
        "D": jnp.ones((Hs,), jnp.float32),
        "dt_bias": jnp.full((Hs,), -4.6, jnp.float32),  # softplus^-1(~0.01)
        "norm_scale": jnp.ones((di,), dt(cfg, "param")),
        "out_proj": (jax.random.normal(ks[3], (di, d)) / math.sqrt(di)).astype(dt(cfg, "param")),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt_raw = proj[..., di + di + 2 * N:]
    assert dt_raw.shape[-1] == Hs
    return z, xBC, dt_raw


def _causal_conv(cfg: ModelConfig, p: Params, xBC: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv (kernel cfg.ssm_conv) over (B, L, C)."""
    conv = cfg.ssm_conv
    pad = jnp.pad(xBC, ((0, 0), (conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    L = xBC.shape[1]
    for j in range(conv):
        out = out + pad[:, j:j + L].astype(jnp.float32) * \
            p["conv_w"][j].astype(jnp.float32)
    out = out + p["conv_b"].astype(jnp.float32)
    return jax.nn.silu(out).astype(xBC.dtype)


def _gated_out(cfg: ModelConfig, p: Params, y: jnp.ndarray, z: jnp.ndarray):
    c = dt(cfg)
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, axis=-1, keepdims=True) + 1e-6)
    g = g * p["norm_scale"].astype(jnp.float32)
    return jnp.einsum("...i,id->...d", g.astype(c), p["out_proj"].astype(c))


def apply_ssd(cfg: ModelConfig, p: Params, xin: jnp.ndarray,
              return_state: bool = False):
    """xin: (B, L, d) -> (B, L, d); L padded internally to a chunk multiple.

    return_state: also return (conv_state, ssd_state) at the final position
    (prefill -> decode handoff)."""
    B, L, d = xin.shape
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    c = dt(cfg)
    Q = min(cfg.ssm_chunk, L)
    Lp = (L + Q - 1) // Q * Q

    proj = jnp.einsum("bld,dp->blp", xin.astype(c), p["in_proj"].astype(c))
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC = _causal_conv(cfg, p, xBC)
    x = xBC[..., :di]
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))   # (B, L, Hs)
    A = -jnp.exp(p["A_log"])                                   # (Hs,)

    if Lp != L:
        padw = ((0, 0), (0, Lp - L), (0, 0))
        x = jnp.pad(x, padw)
        Bm = jnp.pad(Bm, padw)
        Cm = jnp.pad(Cm, padw)
        dtv = jnp.pad(dtv, padw)  # dt=0 -> exp(0)=1 decay, dt x = 0: inert
    nc = Lp // Q
    xh = x.reshape(B, nc, Q, Hs, P).astype(jnp.float32)
    xh = hint(xh, BATCH, None, None, "model", None)
    dtc = hint(dtv.reshape(B, nc, Q, Hs), BATCH, None, None, "model")
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    delta = dtc * A  # (B, nc, Q, Hs), negative
    lam = jnp.cumsum(delta, axis=2)          # Λ_t within chunk
    lam_tot = lam[:, :, -1]                  # (B, nc, Hs)

    # intra-chunk (masked quadratic form)
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)
    decay = jnp.exp(lam[:, :, :, None, :] - lam[:, :, None, :, :])
    # (B, nc, Q(t), Q(s), Hs)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    W = CB[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0)
    W = W * dtc[:, :, None, :, :]            # dt_s
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", W, xh)

    # chunk-final states
    sdecay = jnp.exp(lam_tot[:, :, None, :] - lam) * dtc   # (B, nc, Q, Hs)
    S_c = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", sdecay, xh, Bc)
    S_c = hint(S_c, BATCH, None, "model", None, None)

    def chunk_scan(S_prev, ys):
        S_ci, Cci, lami, lamti = ys
        # y_inter_t = exp(Lam_t) * C_t . S_prev
        y_int = jnp.einsum("bhpn,bqn->bqhp", S_prev, Cci) * \
            jnp.exp(lami)[..., None]
        S_next = jnp.exp(lamti)[:, :, None, None] * S_prev + S_ci
        return S_next, y_int

    S0 = jnp.zeros((B, Hs, P, N), jnp.float32)
    S_fin, y_inter = jax.lax.scan(
        chunk_scan, S0,
        (S_c.transpose(1, 0, 2, 3, 4), Cc.transpose(1, 0, 2, 3),
         lam.transpose(1, 0, 2, 3), lam_tot.transpose(1, 0, 2)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B, nc, Q, Hs, P)

    y = y_intra + y_inter
    y = y + p["D"][None, None, None, :, None] * xh
    y = y.reshape(B, Lp, di)[:, :L]
    out = _gated_out(cfg, p, y.astype(c), z)
    if not return_state:
        return out
    return out, (_conv_tail(cfg, p, xin, proj), S_fin)


def _conv_tail(cfg: ModelConfig, p: Params, xin, proj) -> jnp.ndarray:
    """Last (conv-1) pre-conv xBC rows, the decode-time conv state."""
    _, xBC, _ = _split_proj(cfg, proj)
    k = cfg.ssm_conv - 1
    return xBC[:, -k:, :]


def ssd_step(cfg: ModelConfig, p: Params, xin: jnp.ndarray,
             conv_state: jnp.ndarray, S: jnp.ndarray,
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single decode step.  xin: (B, 1, d); conv_state: (B, conv-1, di+2N)
    pre-activation window; S: (B, Hs, P, N).  Returns (y, conv_state', S')."""
    B = xin.shape[0]
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    c = dt(cfg)
    proj = jnp.einsum("bld,dp->blp", xin.astype(c), p["in_proj"].astype(c))
    z, xBC_new, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_state, xBC_new], axis=1)  # (B, conv, C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[:, :di].reshape(B, Hs, P)
    Bv = conv_out[:, di:di + N]
    Cv = conv_out[:, di + N:]
    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))   # (B, Hs)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dtv * A)                                       # (B, Hs)
    S_new = a[:, :, None, None] * S + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, x, Bv.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cv.astype(jnp.float32))
    y = y + p["D"][None, :, None] * x
    y = y.reshape(B, 1, di)
    out = _gated_out(cfg, p, y.astype(c), z)
    return out, window[:, 1:], S_new


def ssd_reference(cfg: ModelConfig, p: Params, xin: jnp.ndarray) -> jnp.ndarray:
    """Sequential-recurrence oracle (slow, tests only)."""
    B, L, d = xin.shape
    di, N = cfg.d_inner, cfg.ssm_state
    conv_state = jnp.zeros((B, cfg.ssm_conv - 1, di + 2 * N), dt(cfg))
    S = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, N), jnp.float32)
    outs = []
    for t in range(L):
        y, conv_state, S = ssd_step(cfg, p, xin[:, t:t + 1], conv_state, S)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
