"""Capacity-routed top-k Mixture-of-Experts block (kimi-k2, olmoe).

Dispatch is *per batch row* so every routing op (top-k, argsort, capacity
ranking, scatter/gather) is batched over the data-sharded batch dimension
and partitions without communication; the only cross-device movement is the
explicit (batch-sharded -> expert-sharded) boundary around the expert
matmuls, which lowers to the canonical expert-parallel all-to-all on the
production mesh.  Tokens beyond a row's per-expert capacity
ceil(S*K/E * capacity_factor) drop (GShard semantics).

(The first implementation flattened tokens across the global batch before
sorting; GSPMD had to replicate the sort and all-reduce full (T, d)
activations per layer — 16.9 TB/device/step on olmoe train_4k.  The
row-local formulation cut collective traffic ~40x; EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dt
from repro.distributed.hints import BATCH, hint


def init_moe(cfg: ModelConfig, key) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (E, d, f)) * s_in).astype(dt(cfg, "param")),
        "we_up": (jax.random.normal(ks[2], (E, d, f)) * s_in).astype(dt(cfg, "param")),
        "we_down": (jax.random.normal(ks[3], (E, f, d)) * s_out).astype(dt(cfg, "param")),
    }


def _expert_ffn(cfg: ModelConfig, p: Params, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d), batched SwiGLU."""
    c = dt(cfg)
    g = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"].astype(c))
    u = jnp.einsum("ecd,edf->ecf", xe, p["we_up"].astype(c))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(c) * u
    return jnp.einsum("ecf,efd->ecd", h, p["we_down"].astype(c))


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              group_tokens: int = 0) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d).  ``group_tokens`` kept for API compat."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    cap = int(math.ceil(S * K / E * cfg.moe_capacity_factor))
    c = dt(cfg)

    # --- routing (all shapes carry B in dim 0: batch-sharded, local) -------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    top_vals, top_ids = jax.lax.top_k(logits, K)            # (B, S, K)
    gates = jax.nn.softmax(top_vals, axis=-1)
    e_flat = top_ids.reshape(B, S * K)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (B, S * K))
    g_flat = gates.reshape(B, S * K)

    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    t_sorted = jnp.take_along_axis(t_flat, order, axis=1)
    g_sorted = jnp.take_along_axis(g_flat, order, axis=1)
    start = jax.vmap(lambda es: jnp.searchsorted(es, es, side="left"))(e_sorted)
    pos = jnp.arange(S * K, dtype=jnp.int32)[None] - start
    keep = pos < cap
    slot = jnp.where(keep, e_sorted * cap + pos, E * cap)   # OOB drops

    # --- dispatch: row-local scatter into (B, E, cap, d) --------------------
    gathered = jnp.take_along_axis(x.astype(c), t_sorted[..., None], axis=1)
    xd = jax.vmap(lambda buf, sl, gx: buf.at[sl].set(gx, mode="drop"))(
        jnp.zeros((B, E * cap, d), c), slot, gathered)
    xd = xd.reshape(B, E, cap, d)
    # batch-sharded -> expert-sharded on the SAME tensor (no transpose in
    # between): a pure axis swap that GSPMD lowers to the EP all-to-all;
    # resharding after a transpose degenerates to all-gather (§Perf)
    xd = hint(xd, None, ("pod", "model"), None, None)
    xd = xd.transpose(1, 0, 2, 3).reshape(E, B * cap, d)

    ye = _expert_ffn(cfg, p, xd)

    # --- combine: expert-sharded -> batch-sharded, weighted scatter-add ----
    ye = ye.reshape(E, B, cap, d).transpose(1, 0, 2, 3)
    ye = hint(ye, BATCH, None, None, None)      # all-to-all back
    ye = ye.reshape(B, E * cap, d)
    contrib = jnp.take_along_axis(
        ye, jnp.minimum(slot, E * cap - 1)[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0).astype(jnp.float32)
    contrib = contrib * g_sorted[..., None]
    y = jax.vmap(lambda ts, ct: jnp.zeros((S, d), jnp.float32).at[ts].add(ct))(
        t_sorted, contrib)
    return y.astype(x.dtype)
