"""Shared neural layers (pure JAX, functional, dict params).

Memory-critical pieces:
  * ``chunked_attention`` — flash-style online-softmax attention scanned
    over query/KV chunks so 32k-token prefill never materializes S x S
    scores (peak tile: q_chunk x kv_chunk per head group);
  * ``chunked_softmax_xent`` — scans the sequence so 152k-164k vocab logits
    never exist all at once.
All softmax/logsumexp math in fp32; matmul inputs in ``compute_dtype``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.distributed.hints import BATCH, hint

Params = Dict[str, jnp.ndarray]

_MASK = -1e30


def dt(cfg: ModelConfig, kind: str = "compute"):
    return jnp.dtype(cfg.compute_dtype if kind == "compute" else cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, key) -> Params:
    if cfg.norm == "nonparam_ln":
        return {}
    p = {"scale": jnp.ones((cfg.d_model,), dt(cfg, "param"))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dt(cfg, "param"))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = y * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        # nonparam_ln (olmo): no affine parameters
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    assert d % 2 == 0
    freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dt(cfg, "param")),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dt(cfg, "param")),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dt(cfg, "param")),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    c = dt(cfg)
    g = jnp.einsum("...d,df->...f", x.astype(c), p["w_gate"].astype(c))
    u = jnp.einsum("...d,df->...f", x.astype(c), p["w_up"].astype(c))
    nb = (None,) * (x.ndim - 2)
    g = hint(g, BATCH, *nb, "model")
    u = hint(u, BATCH, *nb, "model")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(c) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(c))


# ---------------------------------------------------------------------------
# Flash-style chunked attention
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool,
                      q_positions: jnp.ndarray,
                      kv_positions: jnp.ndarray,
                      q_chunk: int, kv_chunk: int) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D); H = KV * G (GQA).
    q_positions: (Sq,), kv_positions: (Skv,) — used both for causal masking
    and for cache-validity masking at decode (cache slots with position >
    the query position are excluded).
    Scanned over query chunks (outer) and KV chunks (inner): peak live tile
    is (B, KV, G, q_chunk, kv_chunk) in fp32.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = min(q_chunk, Sq)
    while Sq % qc:
        qc -= 1
    kc = min(kv_chunk, Skv)
    while Skv % kc:
        kc -= 1
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / math.sqrt(D)

    qr = q.reshape(B, nq, qc, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KV, G, qc, D); kv-head dim stays on the "model" axis so the
    # score/output tiles compute with sharded heads (GQA with KV < model
    # size is padded by GSPMD — see EXPERIMENTS.md §Perf)
    qr = hint(qr, None, BATCH, "model", None, None, None)
    kr = k.reshape(B, nk, kc, KV, D).transpose(1, 0, 3, 2, 4)  # (nk,B,KV,kc,D)
    vr = v.reshape(B, nk, kc, KV, D).transpose(1, 0, 3, 2, 4)
    kr = hint(kr, None, BATCH, "model", None, None)
    vr = hint(vr, None, BATCH, "model", None, None)
    qp = q_positions.reshape(nq, qc)
    kp = kv_positions.reshape(nk, kc)

    def q_block(carry, xs):
        qt, qpos = xs          # (B,KV,G,qc,D), (qc,)

        def kv_block(acc, ys):
            m, l, o = acc
            kt, vt, kpos = ys  # (B,KV,kc,D), (B,KV,kc,D), (kc,)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                ok = qpos[:, None] >= kpos[None, :]
                s = jnp.where(ok[None, None, None], s, _MASK)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, KV, G, qc), _MASK, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        o0 = jnp.zeros((B, KV, G, qc, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), (kr, vr, kp))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (qr, qp))
    # outs: (nq, B, KV, G, qc, D) -> (B, Sq, H, D)
    outs = hint(outs, None, BATCH, "model", None, None, None)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# GQA attention block (with optional KV cache)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(H * hd)
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dt(cfg, "param")),
        "wk": (jax.random.normal(ks[1], (d, KV, hd)) * s).astype(dt(cfg, "param")),
        "wv": (jax.random.normal(ks[2], (d, KV, hd)) * s).astype(dt(cfg, "param")),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * so).astype(dt(cfg, "param")),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt(cfg, "param"))
        p["bk"] = jnp.zeros((KV, hd), dt(cfg, "param"))
        p["bv"] = jnp.zeros((KV, hd), dt(cfg, "param"))
    return p


def apply_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                    positions: jnp.ndarray,
                    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache_index: Optional[jnp.ndarray] = None,
                    ) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """x: (B, S, d).  Training/prefill: cache=None (returns k, v for cache
    seeding when ``cache_index`` is not None).  Decode: S == 1, ``cache`` =
    (k_cache, v_cache) of shape (B, S_max, KV, hd), ``cache_index`` = scalar
    write position; returns updated cache."""
    c = dt(cfg)
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))
    k = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wk"].astype(c))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wv"].astype(c))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(c)
        k = k + p["bk"].astype(c)
        v = v + p["bv"].astype(c)
    q = hint(q, BATCH, None, "model", None)
    k = hint(k, BATCH, None, "model", None)
    v = hint(v, BATCH, None, "model", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.repeat_kv and cache is None and cfg.num_kv_heads < cfg.num_heads:
        G = cfg.num_heads // cfg.num_kv_heads
        k = hint(jnp.repeat(k, G, axis=2), BATCH, None, "model", None)
        v = hint(jnp.repeat(v, G, axis=2), BATCH, None, "model", None)

    new_cache = None
    if cache is not None:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, cache_index, 0, 0))
        new_cache = (kc, vc)
        k_all, v_all = kc, vc
        kv_pos = jnp.arange(kc.shape[1], dtype=jnp.int32)
        out = chunked_attention(q, k_all, v_all, causal=True,
                                q_positions=positions,
                                kv_positions=kv_pos,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        out = chunked_attention(q, k, v, causal=cfg.causal,
                                q_positions=positions, kv_positions=positions,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        if cache_index is not None:  # prefill: hand back k/v to seed a cache
            new_cache = (k, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    return y, new_cache


# ---------------------------------------------------------------------------
# Embeddings + chunked cross-entropy
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    p = {}
    scale = 1.0 / math.sqrt(cfg.d_model)
    if cfg.frontend in ("tokens", "patch_embed"):
        p["tok"] = (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                    * scale).astype(dt(cfg, "param"))
    if not cfg.tie_embeddings or cfg.frontend == "frame_embed":
        p["unembed"] = (jax.random.normal(k2, (cfg.vocab_size, cfg.d_model))
                        * scale).astype(dt(cfg, "param"))
    return p


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0).astype(dt(cfg))


def unembed_table(cfg: ModelConfig, p: Params) -> jnp.ndarray:
    return p["unembed"] if "unembed" in p else p["tok"]


def logits_last(cfg: ModelConfig, p: Params, h: jnp.ndarray) -> jnp.ndarray:
    """Logits for the last position only (decode / prefill output)."""
    W = unembed_table(cfg, p)
    return jnp.einsum("bd,vd->bv", h[:, -1].astype(jnp.float32),
                      W.astype(jnp.float32))


def chunked_softmax_xent(cfg: ModelConfig, p: Params, h: jnp.ndarray,
                         labels: jnp.ndarray,
                         mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy; scans sequence chunks so only
    (B, chunk, V) logits are ever live.  labels: (B, S) int32; positions with
    label < 0 (or mask == 0) are excluded."""
    B, S, d = h.shape
    W = unembed_table(cfg, p)
    cs = min(cfg.loss_chunk, S)
    while S % cs:
        cs -= 1
    n = S // cs
    hr = h.reshape(B, n, cs, d).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, cs).transpose(1, 0, 2)
    if mask is None:
        mask = (labels >= 0)
    mr = mask.reshape(B, n, cs).transpose(1, 0, 2)

    def body(acc, xs):
        hc, lc, mc = xs
        logits = jnp.einsum("bsd,vd->bsv", hc.astype(dt(cfg)),
                            W.astype(dt(cfg)),
                            preferred_element_type=jnp.float32)
        logits = hint(logits, BATCH, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hr, lr, mr))
    return tot / jnp.maximum(cnt, 1.0)
