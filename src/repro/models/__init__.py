"""Architecture zoo: functional JAX models for all assigned families."""
from .config import ModelConfig, ShapeConfig, SHAPES
from .transformer import (decode_step, embed_inputs, forward_hidden,
                          init_cache, init_params, loss_fn, prefill)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "decode_step",
           "embed_inputs", "forward_hidden", "init_cache", "init_params",
           "loss_fn", "prefill"]
