"""Model assembly for all architecture families.

One stacked-parameter block per family, applied with ``jax.lax.scan`` over
layers (compact HLO, fast compiles, remat-friendly):

  * dense / vlm / audio : [norm -> GQA attention] + [norm -> SwiGLU]
  * moe                 : [norm -> GQA attention] + [norm -> top-k MoE]
  * ssm                 : [norm -> Mamba2/SSD]
  * hybrid (zamba2)     : ssm stack; every ``shared_attn_every`` layers one
                          of ``num_shared_blocks`` *weight-shared* attention
                          blocks is applied (lax.cond inside the scan)

Modality frontends are stubs per the assignment: vlm consumes precomputed
patch embeddings for the first ``num_frontend_tokens`` positions, audio
consumes precomputed frame embeddings (``input_specs`` provides them).

Caches:
  attention: k/v (L, B, S_max, KV, hd);  ssm: conv (L, B, conv-1, di+2N) +
  state (L, B, Hs, P, N); hybrid adds shared-attention k/v of shape
  (n_app, B, S_max, KV, hd) with n_app = num_layers // shared_attn_every.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, apply_attention, apply_mlp, apply_norm, dt,
                     chunked_softmax_xent, embed_tokens, init_attention,
                     init_embed, init_mlp, init_norm, logits_last)
from .moe import apply_moe, init_moe
from repro.distributed.hints import BATCH, hint
from .ssd import apply_ssd, init_ssd, ssd_step

# Full-recompute remat ("none") is the default: minimum live memory per
# layer; "dots" saves matmul outputs (fewer recompute FLOPs/bytes, more
# live memory) — the trade is measured in EXPERIMENTS.md §Perf.
def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


# ---------------------------------------------------------------------------
# per-family block init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    if cfg.family in ("dense", "vlm", "audio"):
        return {"norm1": init_norm(cfg, ks[0]), "attn": init_attention(cfg, ks[1]),
                "norm2": init_norm(cfg, ks[2]), "mlp": init_mlp(cfg, ks[3])}
    if cfg.family == "moe":
        return {"norm1": init_norm(cfg, ks[0]), "attn": init_attention(cfg, ks[1]),
                "norm2": init_norm(cfg, ks[2]), "moe": init_moe(cfg, ks[3])}
    if cfg.family in ("ssm", "hybrid"):
        return {"norm": init_norm(cfg, ks[0]), "ssd": init_ssd(cfg, ks[1])}
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key) -> Params:
    kb, ke, kn, ks, kp = jax.random.split(key, 5)
    layer_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: _init_block(cfg, k))(layer_keys)
    params: Params = {
        "blocks": blocks,
        "embed": init_embed(cfg, ke),
        "final_norm": init_norm(cfg, kn),
    }
    if cfg.family == "hybrid":
        skeys = jax.random.split(ks, cfg.num_shared_blocks)
        params["shared"] = jax.vmap(lambda k: {
            "norm1": init_norm(cfg, jax.random.fold_in(k, 0)),
            "attn": init_attention(cfg, jax.random.fold_in(k, 1)),
            "norm2": init_norm(cfg, jax.random.fold_in(k, 2)),
            "mlp": init_mlp(cfg, jax.random.fold_in(k, 3)),
        })(skeys)
    if cfg.frontend == "patch_embed":
        params["patch_proj"] = (jax.random.normal(kp, (cfg.d_model, cfg.d_model))
                                / math.sqrt(cfg.d_model)).astype(dt(cfg, "param"))
    if cfg.frontend == "frame_embed":
        params["frame_proj"] = (jax.random.normal(kp, (cfg.d_model, cfg.d_model))
                                / math.sqrt(cfg.d_model)).astype(dt(cfg, "param"))
    return params


# ---------------------------------------------------------------------------
# frontends
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
                 ) -> jnp.ndarray:
    if cfg.frontend == "tokens":
        return embed_tokens(cfg, params["embed"], batch["tokens"])
    if cfg.frontend == "patch_embed":
        h = embed_tokens(cfg, params["embed"], batch["tokens"])
        pe = jnp.einsum("bnd,de->bne", batch["patch_embeds"].astype(dt(cfg)),
                        params["patch_proj"].astype(dt(cfg)))
        n_img = pe.shape[1]
        return jnp.concatenate([pe, h[:, n_img:]], axis=1)
    if cfg.frontend == "frame_embed":
        return jnp.einsum("bsd,de->bse", batch["frames"].astype(dt(cfg)),
                          params["frame_proj"].astype(dt(cfg)))
    raise ValueError(cfg.frontend)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _attn_mlp_block(cfg: ModelConfig, bp: Params, h, positions, cache,
                    cache_index, mlp_fn):
    if cfg.seq_parallel and cache is None:
        h = hint(h, BATCH, "model", None)
    a_in = apply_norm(cfg, bp["norm1"], h)
    a_out, new_cache = apply_attention(cfg, bp["attn"], a_in,
                                       positions=positions, cache=cache,
                                       cache_index=cache_index)
    h = h + a_out
    if cfg.seq_parallel and cache is None:
        h = hint(h, BATCH, "model", None)
    m_in = apply_norm(cfg, bp["norm2"], h)
    h = h + mlp_fn(m_in)
    return h, new_cache


def _shared_attn(cfg: ModelConfig, params: Params, h, positions, app_idx: int,
                 shared_cache, cache_index):
    """Hybrid: apply shared block (app_idx % num_shared_blocks) with the
    per-application cache slice ``app_idx``.  app_idx is STATIC (the
    shared-attention schedule is fixed), so parameter/cache selection is a
    static slice — no dynamic gather, exact HLO accounting."""
    blk = jax.tree_util.tree_map(
        lambda a: a[app_idx % cfg.num_shared_blocks], params["shared"])
    cache = None
    if shared_cache is not None:
        cache = (shared_cache["k"][app_idx], shared_cache["v"][app_idx])
    h, new_cache = _attn_mlp_block(cfg, blk, h, positions, cache, cache_index,
                                   lambda m: apply_mlp(cfg, blk["mlp"], m))
    if shared_cache is not None and new_cache is not None:
        kc, vc = new_cache
        shared_cache = {
            "k": shared_cache["k"].at[app_idx].set(kc),
            "v": shared_cache["v"].at[app_idx].set(vc),
        }
    return h, shared_cache


def forward_hidden(cfg: ModelConfig, params: Params, h: jnp.ndarray, *,
                   positions: jnp.ndarray,
                   cache: Optional[Dict[str, jnp.ndarray]] = None,
                   cache_index=None,
                   ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Run the stacked blocks.  ``cache`` semantics:
      * None + cache_index None        -> training forward
      * cache buffers + cache_index    -> decode (or prefill seeding when the
        sequence length equals the buffer length and cache_index == 0)
    """
    fam = cfg.family
    caching = cache is not None

    if fam in ("dense", "moe", "vlm", "audio"):
        def body(carry, xs):
            hh = carry
            bp = xs["block"]
            layer_cache = (xs["k"], xs["v"]) if caching else None
            mlp_fn = ((lambda m: apply_moe(cfg, bp["moe"], m)) if fam == "moe"
                      else (lambda m: apply_mlp(cfg, bp["mlp"], m)))
            hh, new_cache = _attn_mlp_block(cfg, bp, hh, positions,
                                            layer_cache, cache_index, mlp_fn)
            ys = {}
            if caching:
                ys = {"k": new_cache[0], "v": new_cache[1]}
            return hh, ys

        xs = {"block": params["blocks"]}
        if caching:
            xs["k"], xs["v"] = cache["k"], cache["v"]
        if cfg.remat and not caching:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        h, ys = jax.lax.scan(body, h, xs)
        new_cache = {"k": ys["k"], "v": ys["v"]} if caching else None
        return h, new_cache

    if fam in ("ssm", "hybrid"):
        every = cfg.shared_attn_every
        decode = caching and h.shape[1] == 1

        def body(hh, xs):
            bp = xs["block"]
            x_in = apply_norm(cfg, bp["norm"], hh)
            ys = {}
            if decode:
                y, conv2, s2 = ssd_step(cfg, bp["ssd"], x_in, xs["conv"],
                                        xs["state"])
                ys = {"conv": conv2, "state": s2}
            elif caching:  # prefill with state emission
                y, (conv2, s2) = apply_ssd(cfg, bp["ssd"], x_in,
                                           return_state=True)
                ys = {"conv": conv2, "state": s2}
            else:
                y = apply_ssd(cfg, bp["ssd"], x_in)
            return hh + y, ys

        body_fn = body
        if cfg.remat and not caching:
            body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))

        def scan_segment(hh, lo: int, hi: int):
            """Scan ssd layers [lo, hi) of the stacked params (static slice)."""
            xs = {"block": jax.tree_util.tree_map(
                lambda a: a[lo:hi], params["blocks"])}
            if caching:
                xs["conv"] = cache["conv"][lo:hi]
                xs["state"] = cache["state"][lo:hi]
            return jax.lax.scan(body_fn, hh, xs)

        if fam == "ssm":
            h, ys = scan_segment(h, 0, cfg.num_layers)
            new_cache = ({"conv": ys["conv"], "state": ys["state"]}
                         if caching else None)
            return h, new_cache

        # hybrid: python loop over static periods — ssd scan segment, then a
        # weight-shared attention block; exact trip counts in the HLO
        shared_cache = None
        if caching:
            shared_cache = {"k": cache["shared_k"], "v": cache["shared_v"]}
        conv_parts, state_parts = [], []
        n_app = cfg.num_layers // every
        lo = 0
        for app in range(n_app):
            h, ys = scan_segment(h, lo, lo + every)
            lo += every
            if caching:
                conv_parts.append(ys["conv"])
                state_parts.append(ys["state"])
            h, shared_cache = _shared_attn(cfg, params, h, positions, app,
                                           shared_cache, cache_index)
        if lo < cfg.num_layers:  # remainder layers after the last period
            h, ys = scan_segment(h, lo, cfg.num_layers)
            if caching:
                conv_parts.append(ys["conv"])
                state_parts.append(ys["state"])
        new_cache = None
        if caching:
            new_cache = {"conv": jnp.concatenate(conv_parts, axis=0),
                         "state": jnp.concatenate(state_parts, axis=0),
                         "shared_k": shared_cache["k"],
                         "shared_v": shared_cache["v"]}
        return h, new_cache

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# public entry points (loss / prefill / decode)
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
            ) -> jnp.ndarray:
    h = hint(embed_inputs(cfg, params, batch), BATCH, None, None)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    h, _ = forward_hidden(cfg, params, h, positions=positions)
    h = apply_norm(cfg, params["final_norm"], h)
    return chunked_softmax_xent(cfg, params["embed"], h, batch["labels"])


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    L, B, S = cfg.num_layers, batch_size, max_len
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = (L, B, S, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    di, N = cfg.d_inner, cfg.ssm_state
    cache = {
        "conv": jnp.zeros((L, B, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "state": jnp.zeros((L, B, cfg.ssm_heads, cfg.ssm_head_dim, N),
                           jnp.float32),
    }
    if cfg.family == "hybrid":
        n_app = cfg.num_layers // cfg.shared_attn_every
        kv = (n_app, B, S, cfg.num_kv_heads, cfg.head_dim)
        cache["shared_k"] = jnp.zeros(kv, dtype)
        cache["shared_v"] = jnp.zeros(kv, dtype)
    return cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Process the prompt, fill the cache, return last-position logits."""
    h = embed_inputs(cfg, params, batch)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    h, new_cache = forward_hidden(cfg, params, h, positions=positions,
                                  cache=cache, cache_index=0)
    h = apply_norm(cfg, params["final_norm"], h)
    return logits_last(cfg, params["embed"], h), new_cache


def decode_step(cfg: ModelConfig, params: Params,
                cache: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One token for every sequence in the batch.  tokens: (B, 1)."""
    h = embed_tokens(cfg, params["embed"], tokens)
    positions = pos[None].astype(jnp.int32)
    h, new_cache = forward_hidden(cfg, params, h, positions=positions,
                                  cache=cache, cache_index=pos)
    h = apply_norm(cfg, params["final_norm"], h)
    return logits_last(cfg, params["embed"], h), new_cache
