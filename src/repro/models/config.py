"""Model configuration for all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # attention (unused for pure ssm)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    causal: bool = True
    # normalization: rmsnorm | nonparam_ln | layernorm
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): a shared attention block applied every N ssm layers
    shared_attn_every: int = 0
    num_shared_blocks: int = 2
    # modality frontend: tokens | patch_embed | frame_embed
    frontend: str = "tokens"
    num_frontend_tokens: int = 0    # vlm: image positions fed from the stub
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training-memory knobs (per-shape overrides live in launch configs)
    q_chunk: int = 1024
    kv_chunk: int = 2048
    loss_chunk: int = 2048
    remat: bool = True
    remat_policy: str = "none"   # none | dots
    # training-time GQA: materialize K/V at full head count so the head dim
    # shards exactly over the model axis (kv-heads < mesh size otherwise
    # forces GSPMD replication of every attention tensor); caches at decode
    # keep the compact KV layout
    repeat_kv: bool = False
    # EXPERIMENTAL (§Perf C3): shard the residual stream over the model
    # axis on the sequence dim between blocks (sequence parallelism) —
    # norms/elementwise run 1/16th-sized; GSPMD inserts all-gather before
    # attention/mlp and reduce-scatter after
    seq_parallel: bool = False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "vlm", "audio") or \
            self.shared_attn_every > 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid")

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family}")
        if self.family in ("dense", "moe", "vlm", "audio"):
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.family == "hybrid":
            assert self.shared_attn_every > 0 and self.num_heads > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline sanity)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        n = 0
        # embeddings (+ untied head)
        if self.frontend == "tokens" or self.family == "vlm":
            n += V * d
            if not self.tie_embeddings:
                n += V * d
        elif self.family == "audio":
            n += V * d  # classifier head only (frame embeddings are the stub)
        if self.frontend in ("patch_embed", "frame_embed"):
            n += d * d  # frontend adapter projection
        def attn_params() -> int:
            H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
            p = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                p += (H + 2 * KV) * hd
            return p
        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # SwiGLU
        def norm_params() -> int:
            if self.norm == "nonparam_ln":
                return 0
            return 2 * d if self.norm == "layernorm" else d
        def ssm_params() -> int:
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            G = 1  # single B/C group
            p = d * (2 * di + 2 * G * N + Hs)          # in_proj (z,x,B,C,dt)
            p += (self.ssm_conv + 1) * (di + 2 * G * N)  # conv w + bias
            p += Hs * 3                                 # A_log, D, dt_bias
            p += di                                     # gated rmsnorm scale
            p += di * d                                 # out_proj
            return p
        if self.family in ("dense", "vlm", "audio"):
            n += L * (attn_params() + mlp_params(f) + 2 * norm_params())
        elif self.family == "moe":
            n += L * (attn_params() + 2 * norm_params()
                      + self.num_experts * mlp_params(f) + d * self.num_experts)
        elif self.family == "ssm":
            n += L * (ssm_params() + norm_params())
        elif self.family == "hybrid":
            n += L * (ssm_params() + norm_params())
            shared = attn_params() + mlp_params(f) + 2 * norm_params()
            n += self.num_shared_blocks * shared
        n += norm_params()  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of the expert table)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        total = self.param_count()
        expert_all = L * self.num_experts * 3 * d * f
        expert_active = L * self.experts_per_token * 3 * d * f
        return total - expert_all + expert_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: Optional[int] = None   # per-data-shard microbatch rows

SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
