"""Training substrate: optimizer, step builders, loop, data pipeline."""
from .optimizer import AdamWConfig, OptimizerConfig, OptState, init_opt, \
    apply_updates, global_norm
from .step import make_decode_step, make_prefill_step, make_train_step
from .data import DataConfig, SyntheticLM
from .loop import LoopConfig, TrainResult, train
