"""Optimizers: fused AdamW and factored-second-moment Adafactor.

Dtype policy is part of the memory design (DESIGN.md §7):
  * default — AdamW, fp32 m/v, fp32 grad accumulation;
  * trillion-param MoE (kimi-k2) — Adafactor (factored v: O(r + c) state per
    (r, c) matrix instead of O(r*c)), no momentum, bf16 gradient
    accumulation; without this the expert tables alone exceed v5e HBM
    (1.03e12 fp32 grads = 16 GB/device at 256 shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    mode: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    grad_dtype: str = "float32"  # gradient-accumulator dtype
    momentum: bool = True        # adafactor: keep first moment?


# backwards-compatible alias used across the launch stack
AdamWConfig = OptimizerConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any          # first moment (or () when disabled)
    v: Any          # adamw: full second moment; adafactor: (v_row, v_col)


AdamWState = OptState


def _factored(p) -> bool:
    return p.ndim >= 2


def init_opt(cfg: OptimizerConfig, params: Any) -> OptState:
    sdt = jnp.dtype(cfg.state_dtype)

    def zeros_like(p):
        return jnp.zeros(p.shape, sdt)

    if cfg.mode == "adamw":
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree_util.tree_map(zeros_like, params),
                        v=jax.tree_util.tree_map(zeros_like, params))

    def fac(p):
        if not _factored(p):
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}

    m = (jax.tree_util.tree_map(zeros_like, params) if cfg.momentum else ())
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=m,
                    v=jax.tree_util.tree_map(fac, params))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def _adamw_update(cfg, params, grads, state, lr, clip):
    t = (state.step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    pick = lambda i: jax.tree_util.tree_map(
        lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), OptState(step=state.step + 1, m=pick(1), v=pick(2))


def _adafactor_update(cfg, params, grads, state, lr, clip):
    sdt = jnp.dtype(cfg.state_dtype)
    d = 1.0 - cfg.b2  # decay toward running means

    def upd_v(g32, v):
        if "full" in v:
            v_new = {"full": cfg.b2 * v["full"] + d * g32 * g32}
            rms = jnp.sqrt(v_new["full"]) + cfg.eps
            return v_new, g32 / rms
        row = cfg.b2 * v["row"] + d * jnp.mean(g32 * g32, axis=-1)
        col = cfg.b2 * v["col"] + d * jnp.mean(g32 * g32, axis=-2)
        # rank-1 reconstruction of the second moment
        denom = jnp.sqrt(
            row[..., None] * col[..., None, :]
            / (jnp.mean(row, axis=-1)[..., None, None] + 1e-30)) + cfg.eps
        return {"row": row, "col": col}, g32 / denom

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state.v)
    flat_m = treedef.flatten_up_to(state.m) if cfg.momentum else [None] * len(flat_p)

    new_p, new_m, new_v = [], [], []
    for p, g, v, m in zip(flat_p, flat_g, flat_v, flat_m):
        g32 = g.astype(jnp.float32) * clip
        v2, u = upd_v(g32, v)
        if cfg.momentum:
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
            u = m32
            new_m.append(m32.astype(sdt))
        delta = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
        new_v.append(v2)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            OptState(step=state.step + 1,
                     m=(jax.tree_util.tree_unflatten(treedef, new_m)
                        if cfg.momentum else ()),
                     v=jax.tree_util.tree_unflatten(treedef, new_v)))


def apply_updates(cfg: OptimizerConfig, params: Any, grads: Any,
                  state: OptState, lr_scale: "jnp.ndarray | float" = 1.0
                  ) -> Tuple[Any, OptState]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.lr * lr_scale
    if cfg.mode == "adamw":
        return _adamw_update(cfg, params, grads, state, lr, clip)
    return _adafactor_update(cfg, params, grads, state, lr, clip)
