"""Train / serve step builders (the functions the launcher jits and lowers).

``make_train_step``: gradient-accumulation microbatching (scan over
microbatches, fp32 accumulators), fused AdamW, grad-norm metrics.  The
microbatch count is a per-(arch, shape) memory knob — activations live only
for one microbatch (DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import loss_fn as model_loss
from repro.models import decode_step as model_decode
from repro.models import prefill as model_prefill
from repro.models.config import ModelConfig
from .optimizer import AdamWConfig, AdamWState, apply_updates, global_norm


def _split_microbatches(batch: Dict[str, jnp.ndarray], n_micro: int):
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} not divisible by {n_micro} microbatches"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree_util.tree_map(r, batch)


def _drop_axis(ns, axis: str):
    """NamedSharding minus one mesh axis (for loop-hoisted weight gathers)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def fix(e):
        if e == axis:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            return kept if kept else None
        return e

    return NamedSharding(ns.mesh, P(*[fix(e) for e in ns.spec]))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, n_micro: int = 1,
                    grad_shardings: Any = None,
                    gather_weights_once: bool = False) -> Callable:
    """``grad_shardings``: param-tree of NamedSharding — constrains the fp32
    gradient accumulator to the parameter layout (without it GSPMD may
    replicate a param-sized fp32 buffer on every device).

    ``gather_weights_once``: hoist the ZeRO-3 weight all-gather out of the
    gradient-accumulation loop — one bf16 gather per *step* instead of one
    per (layer x microbatch); per-micro grads still reduce-scatter back to
    the 2-D layout so the accumulator stays small (EXPERIMENTS.md §Perf)."""

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, grad_shardings)

    def train_step(params, opt_state: AdamWState, batch):
        compute_params = params
        acc_shardings = grad_shardings
        if gather_weights_once and grad_shardings is not None:
            gathered_sh = jax.tree_util.tree_map(
                lambda ns: _drop_axis(ns, "data"), grad_shardings)
            compute_params = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, params, gathered_sh)
            # accumulate micro-grads in the gathered (model-only) layout:
            # per-device partial sums need NO collective per microbatch; one
            # reduce-scatter back to the 2-D layout happens after the loop
            acc_shardings = gathered_sh
        micro = _split_microbatches(batch, n_micro)

        gdt = jnp.dtype(opt_cfg.grad_dtype)

        def _acc_constrain(tree):
            if acc_shardings is None:
                return tree
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, tree, acc_shardings)

        def one(acc, mb):
            loss, grads = jax.value_and_grad(
                lambda p: model_loss(cfg, p, mb))(compute_params)
            # shard each micro-grad like its accumulator BEFORE accumulating:
            # without this GSPMD may all-gather full fp32 tensors per micro
            grads = _acc_constrain(grads)
            g_acc, l_acc = acc
            g_acc = jax.tree_util.tree_map(
                lambda a, g: (a + g.astype(gdt)).astype(gdt), g_acc, grads)
            return (_acc_constrain(g_acc), l_acc + loss), None

        g0 = _acc_constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, gdt), params))
        (g_sum, loss_sum), _ = jax.lax.scan(one, (g0, jnp.float32(0)), micro)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) / n_micro, g_sum)
        # reshard (reduce over data) to the parameter layout for the update
        grads = _constrain(grads)
        new_params, new_opt = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss_sum / n_micro,
                   "grad_norm": global_norm(grads),
                   "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch, cache):
        return model_prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return model_decode(cfg, params, cache, tokens, pos)
    return decode_step
