"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, host_shard) — after a
failure/restart the pipeline replays exactly, so erasure-coded checkpoint
restores resume bit-identical training (no data-loader state to persist).
The token stream is a stationary Markov chain (learnable structure: loss
decreases measurably within a few hundred steps, unlike uniform noise).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    markov_order: float = 0.9   # prob of structured transition vs uniform


class SyntheticLM:
    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.mc = model_cfg
        v = model_cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        # sparse deterministic successor table: v_next = perm[v] usually
        self.perm = jnp.asarray(rng.permutation(v), jnp.int32)

    def batch_at(self, step: int) -> Dict[str, Any]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)
        B, S, V = self.cfg.batch, self.cfg.seq_len, self.mc.vocab_size
        k1, k2, k3 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (B, 1), 0, V)
        noise = jax.random.randint(k2, (B, S + 1), 0, V)
        use_chain = jax.random.bernoulli(k3, self.cfg.markov_order,
                                         (B, S + 1))

        def step_fn(tok, xs):
            nz, uc = xs
            nxt = jnp.where(uc, self.perm[tok], nz)
            return nxt, nxt

        _, seq = jax.lax.scan(step_fn, start[:, 0],
                              (noise.T, use_chain.T))
        seq = seq.T  # (B, S+1)
        batch: Dict[str, Any] = {"tokens": seq[:, :S],
                                 "labels": seq[:, 1:S + 1]}
        if self.mc.frontend == "patch_embed":
            n = self.mc.num_frontend_tokens
            pk = jax.random.fold_in(key, 7)
            batch["patch_embeds"] = jax.random.normal(
                pk, (B, n, self.mc.d_model), jnp.float32)
            batch["labels"] = batch["labels"].at[:, :n].set(-1)
        if self.mc.frontend == "frame_embed":
            fk = jax.random.fold_in(key, 9)
            batch = {"frames": jax.random.normal(
                fk, (B, S, self.mc.d_model), jnp.float32),
                "labels": batch["labels"]}
        return batch

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
