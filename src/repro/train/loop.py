"""Training loop with erasure-coded checkpointing and failure recovery.

The loop demonstrates the full fault-tolerance story end to end:
  * every ``ckpt_every`` steps the (params, opt_state, step) pytree is
    erasure-coded over a recovery group of hosts (repro.ft);
  * an injected host failure triggers FR/TR/FTR regeneration of the lost
    shard (heterogeneous-link-aware, the paper's contribution), then the
    training state is restored from the group and training resumes;
  * the data pipeline is a pure function of the step, so post-recovery
    training is bit-identical to an uninterrupted run (tested).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ft import ECCheckpoint, ErasureCoder, Fleet, FleetConfig
from repro.models.config import ModelConfig
from repro.models import init_params
from .data import DataConfig, SyntheticLM
from .optimizer import OptimizerConfig, init_opt
from .step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 20
    n_micro: int = 1
    log_every: int = 10
    # recovery group
    ec_n: int = 8
    ec_k: int = 4
    ec_d: int = 6
    blocks_per_host: int = 16
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    final_state: Any
    recoveries: List[Any]
    steps_run: int


def train(model_cfg: ModelConfig, data_cfg: DataConfig,
          opt_cfg: OptimizerConfig, loop_cfg: LoopConfig,
          fail_at: Optional[Dict[int, int]] = None,
          scheme: str = "auto",
          log: Callable[[str], None] = print) -> TrainResult:
    """``fail_at``: {step: host_id} failures injected *after* that step; each
    fires once (the restore rewinds the step counter past it)."""
    fail_at = dict(fail_at or {})
    key = jax.random.PRNGKey(loop_cfg.seed)
    params = init_params(model_cfg, key)
    opt_state = init_opt(opt_cfg, params)
    data = SyntheticLM(data_cfg, model_cfg)
    step_fn = jax.jit(make_train_step(model_cfg, opt_cfg,
                                      n_micro=loop_cfg.n_micro))

    fleet = Fleet(FleetConfig(), seed=loop_cfg.seed)
    coder = ErasureCoder(n=loop_cfg.ec_n, k=loop_cfg.ec_k, d=loop_cfg.ec_d,
                         blocks_per_host=loop_cfg.blocks_per_host,
                         seed=loop_cfg.seed)
    ckpt = ECCheckpoint(fleet, coder, hosts=list(range(loop_cfg.ec_n)),
                        seed=loop_cfg.seed)

    losses: List[float] = []
    step = 0
    while step < loop_cfg.steps:
        t0 = time.perf_counter()
        batch = data.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % loop_cfg.log_every == 0:
            log(f"step {step:4d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"dt {time.perf_counter() - t0:.2f}s")
        if (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state,
                       "step": np.int32(step + 1)}, step + 1)
            log(f"step {step:4d} checkpoint saved "
                f"(EC n={coder.n} k={coder.k} d={coder.d})")
        if step in fail_at:
            host = fail_at.pop(step)
            log(f"step {step:4d} !! host {host} failed")
            if ckpt.group is not None:
                rec = ckpt.on_host_failure(host, scheme=scheme)
                log(f"           regen scheme={rec.decision.plan.scheme} "
                    f"predicted={rec.decision.predicted_s:.3f}s "
                    f"(alternatives: "
                    + " ".join(f"{k}={v:.3f}s"
                               for k, v in rec.decision.alternatives.items())
                    + ")")
                restored = ckpt.restore()
                params, opt_state = restored["params"], restored["opt"]
                step = int(restored["step"]) - 1
                log(f"           restored from EC checkpoint at step "
                    f"{step + 1}; replaying")
        step += 1

    return TrainResult(losses=losses,
                       final_state={"params": params, "opt": opt_state},
                       recoveries=list(ckpt.recoveries),
                       steps_run=len(losses))
